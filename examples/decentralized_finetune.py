"""End-to-end driver: decentralized SeedFlood fine-tuning of an OPT-style
~100M-parameter model for a few hundred steps across 16 clients, with
checkpointing and GMP evaluation — the paper's §4.2 experiment shape on
synthetic data.

    PYTHONPATH=src python examples/decentralized_finetune.py \
        [--steps 300] [--clients 16] [--topology meshgrid] [--small]

--small shrinks the model (for CPU CI); the default is the real opt-125m
config (125M params) which takes a while on one CPU core but is the honest
"train a ~100M model for a few hundred steps" driver.
"""
import argparse

from repro.checkpoint import ckpt
from repro.configs import archs
from repro.core.messages import fmt_bytes
from repro.data.synthetic import TaskConfig
from repro.dtrain.runner import DTrainConfig, run, sim_arch


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--clients", type=int, default=16)
    p.add_argument("--topology", default="meshgrid")
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--flood-k", type=int, default=None)
    p.add_argument("--small", action="store_true")
    p.add_argument("--out", default="/tmp/seedflood_ckpt.npz")
    args = p.parse_args()

    if args.small:
        arch = sim_arch(d_model=64, n_layers=2, n_heads=4, d_ff=128)
    else:
        import dataclasses
        # opt-125m with the synthetic task's vocab (256) — same depth/width,
        # ~86M params; the full 50k vocab would only slow the CPU example
        arch = dataclasses.replace(archs.get("opt-125m"), vocab=256,
                                   name="opt-125m-synth")

    cfg = DTrainConfig(
        method="seedflood", n_clients=args.clients, topology=args.topology,
        steps=args.steps, lr=args.lr, batch_size=8, subcge_rank=32,
        subcge_tau=1000, flood_k=args.flood_k, eval_every=max(args.steps // 5, 1),
        arch=arch, task=TaskConfig(vocab=arch.vocab, seq_len=32,
                                   concentration=0.02))

    print(f"training {arch.name} on {args.clients} clients ({args.topology}), "
          f"{args.steps} steps, flooding k={args.flood_k or 'diameter'}")
    r = run(cfg)

    print(f"\nGMP (averaged-model accuracy): {r.gmp:.4f}")
    print(f"loss: {r.loss_curve[0]:.4f} -> {r.loss_curve[-1]:.4f}")
    for step, acc in r.acc_curve:
        print(f"  step {step:>5}: GMP {acc:.4f}")
    print(f"communication: {fmt_bytes(r.total_bytes)} total, "
          f"{fmt_bytes(r.bytes_per_edge)}/edge, "
          f"{r.extra['n_messages']} messages")
    print(f"consensus error: {r.consensus_error:.2e}")
    if "final_params" in r.extra:
        ckpt.save(args.out, r.extra["final_params"], {"gmp": r.gmp})
        print(f"checkpoint: {args.out}")


if __name__ == "__main__":
    main()
