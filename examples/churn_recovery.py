"""Churn recovery (DESIGN.md §6): the dynamic-topology headline experiment.

A meshgrid of 64 clients trains decentralized; mid-run a block of clients
drops offline (taking their in-flight frontiers with them) and later
rejoins.  SeedFlood recovers via anti-entropy catch-up — rejoined clients
pull exactly the seed-scalar messages they missed and every client's
parameters re-coincide.  The gossip baseline has no such mechanism: its
consensus error jumps on rejoin and only decays at the gossip mixing rate.

    PYTHONPATH=src python examples/churn_recovery.py
    PYTHONPATH=src python examples/churn_recovery.py --clients 16 --steps 12
"""
import argparse

from repro.core.messages import fmt_bytes
from repro.dtrain.runner import DTrainConfig, run, sim_arch
from repro.topology.dynamic import ChurnSchedule


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--clients", type=int, default=64)
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--leave-frac", type=float, default=0.125,
                   help="fraction of clients that churn out")
    p.add_argument("--eval-every", type=int, default=5)
    args = p.parse_args()

    if not 0.0 < args.leave_frac < 1.0:
        raise SystemExit("--leave-frac must be in (0, 1): some clients must "
                         "churn and some must stay to sync them back in")
    n = args.clients
    leave_at = args.steps // 4
    rejoin_at = 3 * args.steps // 4
    churned = tuple(range(0, n, max(1, int(1 / args.leave_frac))))[:max(1, int(n * args.leave_frac))]
    churn = ChurnSchedule.leave_rejoin(churned, leave_at, rejoin_at)
    print(f"{n} clients on a meshgrid; clients {list(churned)} leave at "
          f"t={leave_at}, rejoin (anti-entropy catch-up) at t={rejoin_at}\n")

    arch = sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64)
    common = dict(n_clients=n, topology="meshgrid", steps=args.steps, lr=3e-3,
                  batch_size=8, subcge_rank=16, local_iters=2,
                  eval_every=args.eval_every, churn=churn, arch=arch)

    sf = run(DTrainConfig(method="seedflood", flood_backend="numpy", **common))
    dz = run(DTrainConfig(method="dzsgd", **common))

    print(f"{'step':>6} {'seedflood consensus':>20} {'dzsgd consensus':>20}")
    for (t, e_sf), (_, e_dz) in zip(sf.extra["consensus_curve"],
                                    dz.extra["consensus_curve"]):
        marker = ""
        if t > leave_at and t <= rejoin_at:
            marker = "  <- churned out"
        elif t > rejoin_at:
            marker = "  <- recovered"
        print(f"{t:>6} {e_sf:>20.3e} {e_dz:>20.3e}{marker}")

    print(f"\nfinal consensus: seedflood {sf.consensus_error:.3e} "
          f"(params re-coincide) vs dzsgd {dz.consensus_error:.3e}")
    print(f"final GMP:       seedflood {sf.gmp:.3f} vs dzsgd {dz.gmp:.3f}")
    print(f"comm total:      seedflood {fmt_bytes(sf.total_bytes)} "
          f"(anti-entropy {fmt_bytes(sf.extra['sync_bytes'])} across "
          f"{sf.extra['n_syncs']} syncs) vs dzsgd {fmt_bytes(dz.total_bytes)}")
    if sf.consensus_error < 1e-8 <= dz.consensus_error:
        print("\nSeedFlood recovered exact consensus after churn; "
              "gossip did not.")


if __name__ == "__main__":
    main()
