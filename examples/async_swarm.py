"""Asynchronous swarm (DESIGN.md §9): trace-driven heterogeneous training.

A lognormal-heterogeneous swarm — every client has its own per-step compute
time — trains SeedFlood through the discrete-event engine: no barriers,
flood messages carry per-edge delay, and the sender-epoch replay keeps
arbitrarily stale arrivals exact.  Mid-run one client straggles 3× for a
window and another preempts entirely; a churn schedule also drops and
rejoins a client to show anti-entropy working on the virtual clock.

The run prints loss against *virtual time* next to the synchronous-barrier
baseline on the same trace, where every step waits for the slowest client.

    PYTHONPATH=src python examples/async_swarm.py
    PYTHONPATH=src python examples/async_swarm.py --clients 12 --steps 30
"""
import argparse
import dataclasses

from repro.core.messages import fmt_bytes
from repro.dtrain.runner import DTrainConfig, run, sim_arch
from repro.sim import Episode, TraceSet, barrier_schedule
from repro.topology.dynamic import ChurnSchedule


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--clients", type=int, default=8)
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--sigma", type=float, default=0.5,
                   help="lognormal spread of per-client compute times")
    args = p.parse_args()
    n = args.clients

    base = TraceSet.lognormal(n, median_s=1.0, sigma=args.sigma, seed=7)
    mid = base.ref_step_s * args.steps / 2
    trace = dataclasses.replace(base, episodes=(
        Episode(0, mid, mid + 4 * base.ref_step_s, "straggle", 3.0),
        Episode(1, mid, mid + 2 * base.ref_step_s, "preempt"),
    ))
    churn = ChurnSchedule.leave_rejoin([n - 1], args.steps // 4,
                                       3 * args.steps // 4)
    print(f"{n} clients on a ring, compute {min(trace.compute_s):.2f}-"
          f"{max(trace.compute_s):.2f} s/step; client 0 straggles 3x and "
          f"client 1 preempts mid-run; client {n - 1} churns out "
          f"t={args.steps // 4}..{3 * args.steps // 4}\n")

    cfg = DTrainConfig(
        method="seedflood", n_clients=n, topology="ring", steps=args.steps,
        lr=1e-2, batch_size=4, subcge_rank=8, trace=trace, churn=churn,
        arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    r = run(cfg)

    curve = r.extra["loss_vs_virtual_time"]
    barrier_end = barrier_schedule(trace, args.steps)[-1]
    print(f"{'virtual s':>10} {'loss':>8}")
    stride = max(1, len(curve) // 12)
    for vt, loss in curve[::stride]:
        print(f"{vt:>10.2f} {loss:>8.4f}")
    print(f"\nasync finished in {r.extra['virtual_time_s']:.1f} virtual s "
          f"(barrier baseline: {barrier_end:.1f} s), "
          f"{len(curve)} cohort dispatches, "
          f"{fmt_bytes(r.total_bytes)} flooded, gmp={r.gmp:.3f}")


if __name__ == "__main__":
    main()
