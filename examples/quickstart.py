"""Quickstart: decentralized SeedFlood fine-tuning of a tiny decoder on a
ring of 8 clients, vs the DZSGD gossip baseline.

    PYTHONPATH=src python examples/quickstart.py [--steps 120]
"""
import argparse

from repro.core.messages import fmt_bytes
from repro.dtrain.runner import DTrainConfig, run, sim_arch


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=120,
                   help="training steps (lower for a CI smoke run)")
    args = p.parse_args()

    arch = sim_arch(d_model=48, n_layers=2, n_heads=4, d_ff=96)
    from repro.data.synthetic import TaskConfig
    common = dict(n_clients=8, topology="ring", steps=args.steps, lr=3e-3,
                  batch_size=16, subcge_rank=32, arch=arch,
                  task=TaskConfig(vocab=256, seq_len=16, concentration=0.02))

    sf = run(DTrainConfig(method="seedflood", **common))
    dz = run(DTrainConfig(method="dzsgd", **common))

    print(f"{'method':<12} {'GMP':>6} {'bytes/edge':>12} {'consensus':>10}")
    for r in (sf, dz):
        print(f"{r.method:<12} {r.gmp:>6.3f} "
              f"{fmt_bytes(r.bytes_per_edge):>12} {r.consensus_error:>10.2e}")
    ratio = dz.total_bytes / max(sf.total_bytes, 1)
    print(f"\nSeedFlood uses {ratio:,.0f}x less communication "
          f"({fmt_bytes(sf.total_bytes)} vs {fmt_bytes(dz.total_bytes)} total)")


if __name__ == "__main__":
    main()
