"""Serve a small model with batched requests: prefill a batch of prompts,
then decode tokens autoregressively with the production decode_step — the
same program the decode_32k / long_500k dry-runs lower at pod scale.

    PYTHONPATH=src python examples/serve_decode.py [--arch tinyllama-1.1b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.base import InputShape
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh
from repro.models import params as plib
from repro.models import transformer as tf


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    args = p.parse_args()

    cfg = archs.reduced(archs.get(args.arch))
    mesh = make_host_mesh(1, 1)
    pod = steplib.PodConfig(param_dtype=jnp.float32)
    capacity = args.prompt_len + args.new_tokens

    shape_p = InputShape("serve", capacity, args.batch, "prefill")
    prefill, _, _, _ = steplib.build_prefill_step(cfg, shape_p, mesh, pod)
    decode, _, _, _ = steplib.build_decode_step(
        cfg, InputShape("serve", capacity, args.batch, "decode"), mesh, pod)

    params = plib.init_params(tf.arch_spec(cfg), 0)
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)

    # prefill builds the cache over full capacity; we pass the prompt only
    cache = tf.init_cache(cfg, args.batch, capacity, jnp.float32)
    with mesh:
        logits, cache, _ = tf.forward(cfg, params,
                                      {"tokens": prompts}, cache=cache, pos=0)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for i in range(args.new_tokens - 1):
            logits1, cache = jax.jit(decode)(params, cache, tok,
                                             jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits1, axis=-1)[:, None]
            out.append(tok)
        dt = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"decode throughput: "
          f"{args.batch * (args.new_tokens - 1) / dt:.1f} tok/s (host CPU)")
    for b in range(args.batch):
        print(f"  req{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
