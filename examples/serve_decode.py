"""Serve a small model with continuous batching: requests admit into slots,
prefill scatters KV into reserved pages, and each step decodes one token
for every active slot through repro.serve's paged decode program.

    PYTHONPATH=src python examples/serve_decode.py [--arch tinyllama-1.1b]

Compiled programs are cached inside the server per shape — (batch, prompt
length) for prefill, page bucket for decode — so the decode loop dispatches
the SAME compiled program every step.  (An earlier version of this example
re-traced ``jax.jit(decode)`` on every loop iteration, recompiling per
token; throughput numbers from it measured the compiler, not the model.)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import archs
from repro.models import params as plib
from repro.models import transformer as tf
from repro.serve import DecodeServer, Request, ServeConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--sampling", default="greedy",
                   choices=("greedy", "temperature"))
    args = p.parse_args()

    cfg = archs.reduced(archs.get(args.arch))
    page = min(16, args.prompt_len + args.new_tokens)
    ppr = -(-(args.prompt_len + args.new_tokens) // page)
    serve = ServeConfig(max_batch=args.batch, page_size=page,
                        n_pages=args.batch * ppr, max_seq=ppr * page,
                        sampling=args.sampling)

    params = plib.init_params(tf.arch_spec(cfg), 0)
    prompts = jax.random.randint(jax.random.PRNGKey(0),
                                 (args.requests, args.prompt_len), 0,
                                 cfg.vocab)

    srv = DecodeServer(cfg, params, serve)
    for b in range(args.requests):
        srv.submit(Request(rid=b, prompt=np.asarray(prompts[b], np.int32),
                           max_new=args.new_tokens))
    t0 = time.perf_counter()
    results = srv.run()
    dt = time.perf_counter() - t0

    emitted = sum(len(v) for v in results.values())
    print(f"arch={cfg.name} slots={args.batch} requests={args.requests} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"decode throughput: {emitted / dt:.1f} tok/s (host CPU); "
          f"{srv.stats()}")
    for b in range(args.requests):
        print(f"  req{b}: {results[b]}")


if __name__ == "__main__":
    main()
