"""Delayed flooding (paper §4.5): sweep the flooding-steps hyperparameter k
on a ring of 16 clients and watch GMP/consensus vs staleness bound ⌈D/k⌉.

With ``--tau`` below the staleness bound, messages are replayed in a later
subspace epoch than they were sent — the regime where the epoch-correct
replay (DESIGN.md §2) is load-bearing.  ``--drain`` flushes in-flight
messages after the last step so the consensus column reflects delivery of
every message rather than the final ⌈D/k⌉ steps' in-flight gap.

    PYTHONPATH=src python examples/delayed_flooding.py [--steps 60] [--tau 2 --drain]
"""
import argparse

from repro.core import flood
from repro.dtrain.runner import DTrainConfig, run, sim_arch
from repro.topology import graphs


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--clients", type=int, default=16)
    p.add_argument("--tau", type=int, default=1000,
                   help="SubCGE refresh period; < staleness bound exercises "
                        "cross-epoch replay")
    p.add_argument("--drain", action="store_true",
                   help="flood to quiescence after the last step")
    args = p.parse_args()

    diam = graphs.diameter(graphs.ring(args.clients))
    print(f"ring of {args.clients}: diameter D = {diam}, tau = {args.tau}\n"
          f"{'k':>6} {'staleness≤':>10} {'GMP':>7} {'consensus':>10} {'bytes/edge':>11}")
    for k in [None, diam, 4, 2, 1]:
        r = run(DTrainConfig(
            method="seedflood", n_clients=args.clients, topology="ring",
            steps=args.steps, lr=3e-3, batch_size=16, subcge_rank=32,
            subcge_tau=args.tau, flood_k=k, drain=args.drain,
            arch=sim_arch(d_model=48, n_layers=2, n_heads=4, d_ff=96)))
        kk = k or diam
        print(f"{'full' if k is None else k:>6} "
              f"{flood.staleness_bound(diam, kk):>10} {r.gmp:>7.3f} "
              f"{r.consensus_error:>10.2e} {r.bytes_per_edge:>11.0f}")


if __name__ == "__main__":
    main()
