"""Dynamic topology: timed churn over a base communication graph (DESIGN.md §6).

The paper evaluates SeedFlood on *static, connected* topologies; real
decentralized deployments churn — clients come and go, links flap, the
network transiently partitions.  This module is the churn layer shared by
the flood protocol (``repro.core.flood``) and the gossip baselines
(``repro.dtrain.runner``):

* ``ChurnEvent`` / ``ChurnSchedule`` — a declarative, step-indexed script of
  topology mutations (node leave/join, link failure/recovery, transient
  partitions) plus seeded random-churn generators, so experiments are
  exactly reproducible.
* ``DynamicTopology`` — the mutable view of a base graph: which nodes are
  online, which links are up, current neighbour lists, and the effective
  (per-component) diameter.  Protocols consume deltas (``TopologyDelta``)
  describing what changed, e.g. which edges were restored — the trigger for
  the flood layer's anti-entropy sync.

The base graph stays fixed; churn toggles membership of its nodes and
edges.  That matches the paper's deployment model (a known overlay whose
participants are unreliable) and keeps every mutation invertible.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import networkx as nx
import numpy as np


EVENT_KINDS = ("leave", "join", "link_down", "link_up", "partition", "heal")


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """One timed topology mutation, applied at the *start* of ``step``."""
    step: int
    kind: str                                   # one of EVENT_KINDS
    nodes: tuple[int, ...] = ()                 # leave / join
    edges: tuple[tuple[int, int], ...] = ()     # link_down / link_up
    groups: tuple[tuple[int, ...], ...] = ()    # partition

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown churn event kind '{self.kind}'")
        if self.step < 0:
            raise ValueError("churn events must be scheduled at step >= 0")
        if self.kind in ("leave", "join") and not self.nodes:
            raise ValueError(f"'{self.kind}' event needs nodes")
        if self.kind in ("link_down", "link_up") and not self.edges:
            raise ValueError(f"'{self.kind}' event needs edges")
        if self.kind == "partition" and len(self.groups) < 2:
            raise ValueError("'partition' event needs >= 2 groups")


class ChurnSchedule:
    """An immutable, step-sorted script of :class:`ChurnEvent`."""

    def __init__(self, events: Iterable[ChurnEvent]):
        self.events: tuple[ChurnEvent, ...] = tuple(
            sorted(events, key=lambda e: e.step))
        self._by_step: dict[int, list[ChurnEvent]] = {}
        for ev in self.events:
            self._by_step.setdefault(ev.step, []).append(ev)

    def events_at(self, step: int) -> list[ChurnEvent]:
        return self._by_step.get(step, [])

    @property
    def horizon(self) -> int:
        """Last step carrying an event (-1 for the empty schedule)."""
        return self.events[-1].step if self.events else -1

    def __len__(self) -> int:
        return len(self.events)

    def __add__(self, other: "ChurnSchedule") -> "ChurnSchedule":
        return ChurnSchedule(self.events + other.events)

    # -- builders -------------------------------------------------------------

    @classmethod
    def leave_rejoin(cls, nodes: Sequence[int], leave_at: int,
                     rejoin_at: int) -> "ChurnSchedule":
        """The headline experiment: ``nodes`` go offline at ``leave_at`` and
        come back (with anti-entropy catch-up) at ``rejoin_at``."""
        if rejoin_at <= leave_at:
            raise ValueError("rejoin_at must come after leave_at")
        nodes = tuple(nodes)
        return cls([ChurnEvent(leave_at, "leave", nodes=nodes),
                    ChurnEvent(rejoin_at, "join", nodes=nodes)])

    @classmethod
    def link_flap(cls, edges: Sequence[tuple[int, int]], down_at: int,
                  up_at: int) -> "ChurnSchedule":
        if up_at <= down_at:
            raise ValueError("up_at must come after down_at")
        edges = tuple((int(u), int(v)) for u, v in edges)
        return cls([ChurnEvent(down_at, "link_down", edges=edges),
                    ChurnEvent(up_at, "link_up", edges=edges)])

    @classmethod
    def partition(cls, groups: Sequence[Sequence[int]], at: int,
                  heal_at: int) -> "ChurnSchedule":
        """Transient partition: every base edge crossing the groups fails at
        ``at`` and is restored (triggering anti-entropy) at ``heal_at``."""
        if heal_at <= at:
            raise ValueError("heal_at must come after at")
        gs = tuple(tuple(int(i) for i in g) for g in groups)
        return cls([ChurnEvent(at, "partition", groups=gs),
                    ChurnEvent(heal_at, "heal")])

    @classmethod
    def random_churn(cls, n: int, steps: int, rate: float, seed: int = 0,
                     outage: tuple[int, int] = (5, 15),
                     max_concurrent: int = 1) -> "ChurnSchedule":
        """Seeded random node churn: each online node leaves with per-step
        probability ``rate`` (at most ``max_concurrent`` offline at once) and
        rejoins after a uniform outage of ``outage`` steps, clamped so every
        node is back online before ``steps``."""
        rng = np.random.default_rng(seed)
        events: list[ChurnEvent] = []
        offline: dict[int, int] = {}            # node -> rejoin step
        for t in range(steps):
            for node, back in list(offline.items()):
                if back == t:
                    events.append(ChurnEvent(t, "join", nodes=(node,)))
                    del offline[node]
            for node in range(n):
                if node in offline or len(offline) >= max_concurrent:
                    continue
                if rng.random() < rate:
                    lo, hi = outage
                    back = t + int(rng.integers(lo, hi + 1))
                    back = min(back, steps - 1)
                    if back <= t:
                        continue
                    events.append(ChurnEvent(t, "leave", nodes=(node,)))
                    offline[node] = back
        # back is always clamped into (t, steps-1], so the matching join was
        # emitted inside the loop — no node can be left offline at the horizon
        assert not offline
        return cls(events)

    @classmethod
    def from_config(cls, cfg) -> "ChurnSchedule":
        """Resolve a declarative ``repro.configs.base.ChurnConfig``."""
        if cfg.kind == "leave_rejoin":
            return cls.leave_rejoin(cfg.nodes, cfg.leave_at, cfg.rejoin_at)
        if cfg.kind == "link_flap":
            return cls.link_flap(cfg.edges, cfg.leave_at, cfg.rejoin_at)
        if cfg.kind == "partition":
            return cls.partition(cfg.groups, cfg.leave_at, cfg.rejoin_at)
        if cfg.kind == "random":
            return cls.random_churn(cfg.n, cfg.steps, cfg.rate, cfg.seed,
                                    cfg.outage, cfg.max_concurrent)
        raise ValueError(f"unknown churn kind '{cfg.kind}'")


@dataclasses.dataclass
class TopologyDelta:
    """What one event (or batch of events) changed — consumed by protocols."""
    left: list[int] = dataclasses.field(default_factory=list)
    joined: list[tuple[int, int | None]] = dataclasses.field(
        default_factory=list)              # (node, sync partner or None)
    downed: list[tuple[int, int]] = dataclasses.field(default_factory=list)
    restored: list[tuple[int, int]] = dataclasses.field(default_factory=list)

    def merge(self, other: "TopologyDelta") -> None:
        self.left += other.left
        self.joined += other.joined
        self.downed += other.downed
        self.restored += other.restored


class DynamicTopology:
    """Mutable membership view over a fixed base graph.

    Nodes are 0..n-1 forever; ``leave``/``join`` toggle whether a node
    participates, ``fail_link``/``restore_link`` toggle base edges, and
    ``partition``/``heal`` fail/restore the cut between node groups.  A
    message-passing edge is *live* iff it is a base edge, not failed, and
    both endpoints are online.
    """

    def __init__(self, graph: nx.Graph):
        if graph.number_of_nodes() == 0:
            raise ValueError("empty graph")
        if not nx.is_connected(graph):
            raise ValueError("SeedFlood assumes a connected communication graph")
        self.base_graph = graph.copy()
        self.n = graph.number_of_nodes()
        self._online = [True] * self.n
        self._down: set[frozenset] = set()
        self._partition_cut: set[frozenset] = set()
        self._dirty = True                  # neighbour lists stale
        self._diam_dirty = True             # effective diameter stale
        self._nbrs: list[list[int]] | None = None
        self._eff_diam: int | None = None
        self.version = 0                    # bumped on every mutation

    # -- queries --------------------------------------------------------------

    def is_active(self, i: int) -> bool:
        return self._online[i]

    def active_mask(self) -> np.ndarray:
        return np.asarray(self._online, dtype=bool)

    def n_active(self) -> int:
        return sum(self._online)

    def edge_live(self, u: int, v: int) -> bool:
        return (self.base_graph.has_edge(u, v)
                and frozenset((u, v)) not in self._down
                and self._online[u] and self._online[v])

    def live_edge_count(self) -> int:
        return sum(1 for u, v in self.base_graph.edges()
                   if self.edge_live(u, v))

    def neighbors(self) -> list[list[int]]:
        """Per-node sorted list of live neighbours (empty for offline nodes)."""
        self._refresh()
        return self._nbrs

    def current_graph(self) -> nx.Graph:
        """All n nodes, only live edges (offline nodes are isolated)."""
        g = nx.Graph()
        g.add_nodes_from(range(self.n))
        g.add_edges_from((u, v) for u, v in self.base_graph.edges()
                         if self.edge_live(u, v))
        return g

    def effective_diameter(self) -> int:
        """Max diameter over connected components of live online nodes — the
        number of flood rounds that guarantees component-wide coverage.
        Cached separately from the neighbour lists: the all-pairs BFS is the
        expensive part and most mutations never ask for it."""
        if self._diam_dirty:
            self._refresh()
            self._eff_diam = self._max_component_diameter()
            self._diam_dirty = False
        return self._eff_diam

    def is_connected(self) -> bool:
        g = self.current_graph()
        online = [i for i in range(self.n) if self._online[i]]
        if not online:
            return False
        return nx.is_connected(g.subgraph(online))

    def _refresh(self) -> None:
        if not self._dirty:
            return
        nbrs: list[list[int]] = [[] for _ in range(self.n)]
        for u, v in self.base_graph.edges():
            if self.edge_live(u, v):
                nbrs[u].append(v)
                nbrs[v].append(u)
        self._nbrs = [sorted(ns) for ns in nbrs]
        self._dirty = False

    def _max_component_diameter(self) -> int:
        try:                        # C BFS — this runs on every churn event
            import scipy.sparse as sp
            from scipy.sparse.csgraph import shortest_path
            rows = [u for u, ns in enumerate(self._nbrs) for _ in ns]
            cols = [v for ns in self._nbrs for v in ns]
            adj = sp.csr_matrix((np.ones(len(rows), np.int8), (rows, cols)),
                                shape=(self.n, self.n))
            dist = shortest_path(adj, method="D", unweighted=True)
            finite = dist[np.isfinite(dist)]
            return int(finite.max()) if finite.size else 0
        except ImportError:
            g = self.current_graph()
            online = [i for i in range(self.n) if self._online[i]]
            diam = 0
            if online:
                sub = g.subgraph(online)
                for comp in nx.connected_components(sub):
                    if len(comp) > 1:
                        diam = max(diam, nx.diameter(sub.subgraph(comp)))
            return diam

    # -- mutations ------------------------------------------------------------

    def _mutated(self) -> None:
        self._dirty = True
        self._diam_dirty = True
        self.version += 1

    def leave(self, i: int) -> None:
        if not self._online[i]:
            raise ValueError(f"node {i} is already offline")
        self._online[i] = False
        self._mutated()

    def join(self, i: int) -> int | None:
        """Bring node ``i`` back online; returns the lowest-id live neighbour
        (the anti-entropy sync partner) or None if it rejoins isolated."""
        if self._online[i]:
            raise ValueError(f"node {i} is already online")
        self._online[i] = True
        self._mutated()
        self._refresh()
        ns = self._nbrs[i]
        return ns[0] if ns else None

    def fail_link(self, u: int, v: int) -> None:
        if not self.base_graph.has_edge(u, v):
            raise ValueError(f"({u},{v}) is not a base edge")
        self._down.add(frozenset((u, v)))
        self._mutated()

    def restore_link(self, u: int, v: int) -> bool:
        """Returns True if the link was actually down (and is now restored)."""
        e = frozenset((u, v))
        if e in self._down:
            self._down.discard(e)
            self._partition_cut.discard(e)
            self._mutated()
            return True
        return False

    def partition(self, groups: Sequence[Sequence[int]]) -> list[tuple[int, int]]:
        """Fail every live base edge crossing the groups; remembers the cut so
        ``heal`` can restore exactly it."""
        side = {}
        for gi, g in enumerate(groups):
            for node in g:
                side[node] = gi
        cut = []
        for u, v in self.base_graph.edges():
            if side.get(u) is not None and side.get(v) is not None \
                    and side[u] != side[v] \
                    and frozenset((u, v)) not in self._down:
                self._down.add(frozenset((u, v)))
                self._partition_cut.add(frozenset((u, v)))
                cut.append((u, v))
        self._mutated()
        return cut

    def heal(self) -> list[tuple[int, int]]:
        restored = []
        for e in sorted(self._partition_cut, key=sorted):
            u, v = sorted(e)
            self._down.discard(e)
            restored.append((u, v))
        self._partition_cut.clear()
        self._mutated()
        return restored

    # -- checkpointing ---------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-serializable membership state (the base graph is rebuilt
        from config at resume, so only the mutable overlay is captured)."""
        return {
            "online": [bool(x) for x in self._online],
            "down": sorted(sorted(e) for e in self._down),
            "partition_cut": sorted(sorted(e) for e in self._partition_cut),
        }

    def load_state_dict(self, state: dict) -> None:
        self._online = [bool(x) for x in state["online"]]
        self._down = {frozenset((int(u), int(v)))
                      for u, v in state["down"]}
        self._partition_cut = {frozenset((int(u), int(v)))
                               for u, v in state["partition_cut"]}
        self._mutated()

    # -- event application ----------------------------------------------------

    def apply_event(self, ev: ChurnEvent) -> TopologyDelta:
        d = TopologyDelta()
        if ev.kind == "leave":
            for i in ev.nodes:
                self.leave(i)
                d.left.append(i)
        elif ev.kind == "join":
            for i in ev.nodes:
                d.joined.append((i, self.join(i)))
        elif ev.kind == "link_down":
            for u, v in ev.edges:
                self.fail_link(u, v)
                d.downed.append((u, v))
        elif ev.kind == "link_up":
            for u, v in ev.edges:
                if self.restore_link(u, v):
                    d.restored.append((u, v))
        elif ev.kind == "partition":
            d.downed += self.partition(ev.groups)
        elif ev.kind == "heal":
            d.restored += self.heal()
        return d

    def apply_events(self, events: Iterable[ChurnEvent]) -> TopologyDelta:
        d = TopologyDelta()
        for ev in events:
            d.merge(self.apply_event(ev))
        return d
