"""Network topologies for decentralized training (paper §2.1, §4.1).

Graphs are plain ``networkx`` undirected graphs over client ids 0..n-1.  We
provide the paper's two evaluation topologies (ring, meshgrid) plus the usual
suspects for property tests, along with the quantities the algorithms need:
diameter, neighbour lists, and gossip mixing matrices.
"""
from __future__ import annotations

import math

import networkx as nx
import numpy as np


def ring(n: int) -> nx.Graph:
    return nx.cycle_graph(n)


def meshgrid(n: int) -> nx.Graph:
    """2D grid with ~square aspect (paper's 'mesh-grid'); n need not be a
    perfect square — we use the most-square factorization."""
    rows = int(math.isqrt(n))
    while n % rows != 0:
        rows -= 1
    cols = n // rows
    g = nx.grid_2d_graph(rows, cols)
    return nx.convert_node_labels_to_integers(g, ordering="sorted")


def torus(n: int) -> nx.Graph:
    rows = int(math.isqrt(n))
    while n % rows != 0:
        rows -= 1
    cols = n // rows
    g = nx.grid_2d_graph(rows, cols, periodic=(rows > 2 and cols > 2))
    return nx.convert_node_labels_to_integers(g, ordering="sorted")


def star(n: int) -> nx.Graph:
    return nx.star_graph(n - 1)


def complete(n: int) -> nx.Graph:
    return nx.complete_graph(n)


def erdos_renyi(n: int, p: float, seed: int = 0) -> nx.Graph:
    """Connected G(n, p): resample until connected (p should be above the
    connectivity threshold ln(n)/n)."""
    rng = np.random.default_rng(seed)
    for _ in range(512):
        g = nx.erdos_renyi_graph(n, p, seed=int(rng.integers(2**31)))
        if nx.is_connected(g):
            return g
    raise ValueError(f"could not sample a connected G({n},{p})")


TOPOLOGIES = {
    "ring": ring,
    "meshgrid": meshgrid,
    "torus": torus,
    "star": star,
    "complete": complete,
}


def make(name: str, n: int) -> nx.Graph:
    if name not in TOPOLOGIES:
        raise KeyError(f"unknown topology '{name}' (have {sorted(TOPOLOGIES)})")
    return TOPOLOGIES[name](n)


def diameter(g: nx.Graph) -> int:
    return nx.diameter(g)


def neighbors(g: nx.Graph) -> list[list[int]]:
    return [sorted(g.neighbors(i)) for i in range(g.number_of_nodes())]


def metropolis_weights(g: nx.Graph) -> np.ndarray:
    """Metropolis–Hastings mixing matrix: symmetric, doubly stochastic,
    w_ij = 1/(1+max(deg_i,deg_j)) on edges — the standard gossip W."""
    n = g.number_of_nodes()
    W = np.zeros((n, n))
    deg = dict(g.degree())
    for i, j in g.edges():
        w = 1.0 / (1.0 + max(deg[i], deg[j]))
        W[i, j] = W[j, i] = w
    for i in range(n):
        W[i, i] = 1.0 - W[i].sum()
    return W


def spectral_gap(W: np.ndarray) -> float:
    """1 - λ2: gossip consensus speed (0 for disconnected)."""
    eig = np.sort(np.abs(np.linalg.eigvalsh(W)))
    return float(1.0 - eig[-2])
