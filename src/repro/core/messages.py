"""Seed-scalar messages and byte accounting (paper §3.1, Table 1, Fig. 1).

A SeedFlood wire message is ``(seed, coef, step)``: a 4-byte uint32 seed, a
2-byte fp16 coefficient, and a 2-byte header whose dedup id *is* the sender
step (uid = (origin, step mod 2^16), matching the ``client_seed`` layout
where steps fit in 16 bits).  The paper quotes ~400 KB for 5000 iterations ×
16 clients per edge, i.e. ≈5 B/message; we stay conservative at 8 B.

Carrying the sender step on the wire is load-bearing, not bookkeeping: a
receiver must replay every message under the SubCGE subspace of the
*sender's* τ-epoch (``step // τ``), which can differ from its own whenever
delayed flooding or an outage lets staleness cross a refresh boundary
(DESIGN.md §6).  The ledger tracks *bytes per edge* — the paper's
communication-cost metric — for every protocol so Fig. 1/3 and Table 8 can
be reproduced exactly.
"""
from __future__ import annotations

import dataclasses


SEED_BYTES = 4      # uint32 seed
COEF_BYTES = 2      # fp16 scalar
HEADER_BYTES = 2    # dedup id == sender step mod 2^16 (uid + epoch replay)
MESSAGE_BYTES = SEED_BYTES + COEF_BYTES + HEADER_BYTES

# Anti-entropy (DESIGN.md §6): a rejoining client and its sync partner
# exchange compact seen-set digests (1 byte of truncated uid hash per entry
# plus a fixed frame) before re-sending only the set difference.
DIGEST_HEADER_BYTES = 8
DIGEST_BYTES_PER_MSG = 1


def digest_bytes(n_seen: int) -> int:
    """Wire size of one seen-set digest covering ``n_seen`` message uids."""
    return DIGEST_HEADER_BYTES + n_seen * DIGEST_BYTES_PER_MSG


def pad_pow2(k: int, minimum: int = 4) -> int:
    """Smallest power-of-two bucket >= k.  All padded payload widths (the
    K message columns, the E epoch slots) quantize through this one function
    so jit retraces stay bounded by a single policy."""
    n = max(1, minimum)
    while n < k:
        n *= 2
    return n


@dataclasses.dataclass(frozen=True)
class Message:
    """One seed-reconstructible ZO update m = (s, α·η/n)."""
    seed: int          # s_{i,t} — reconstructs the perturbation anywhere
    coef: float        # the *fixed* coefficient (flooding never reweights it)
    origin: int        # producing client (debug/bookkeeping only)
    step: int          # producing iteration — fixes the sender's subspace
                       # epoch (step // τ) that any replay must regenerate

    @property
    def uid(self) -> tuple[int, int]:
        return (self.origin, self.step)

    @property
    def nbytes(self) -> int:
        return MESSAGE_BYTES


@dataclasses.dataclass
class CommLedger:
    """Byte counters, kept per protocol run.

    ``per_edge`` is the paper's reported metric: total transmitted volume over
    each network edge during the entire training (Table 8 'Cost').
    """
    total_bytes: int = 0
    n_edges: int = 1
    n_messages: int = 0
    rounds: int = 0
    sync_bytes: int = 0       # anti-entropy digests + re-sent messages
    n_syncs: int = 0          # pairwise digest exchanges

    def send(self, nbytes: int, count: int = 1) -> None:
        self.total_bytes += nbytes
        self.n_messages += count

    def sync(self, nbytes: int, count: int = 0) -> None:
        """Charge one anti-entropy exchange (counts toward total_bytes)."""
        self.total_bytes += nbytes
        self.sync_bytes += nbytes
        self.n_messages += count
        self.n_syncs += 1

    @property
    def per_edge(self) -> float:
        return self.total_bytes / max(1, self.n_edges)


def dense_payload_bytes(n_params: int, dtype_bytes: int = 4) -> int:
    """Bytes to gossip one full model copy (traditional gossip, O(d))."""
    return n_params * dtype_bytes


def topk_payload_bytes(n_params: int, density: float, dtype_bytes: int = 4,
                       index_bytes: int = 4) -> int:
    """ChocoSGD-style top-k sparsified payload: values + indices."""
    k = max(1, int(n_params * density))
    return k * (dtype_bytes + index_bytes)


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024.0:
            return f"{b:.2f}{unit}"
        b /= 1024.0
    return f"{b:.2f}EB"
