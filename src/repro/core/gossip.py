"""Gossip-based baselines (paper §2.1, §4.2).

All baselines operate on *stacked* client parameters — pytrees whose leaves
carry a leading client axis ``(n, ...)`` — so the whole network simulates as
vectorized JAX ops:

* ``mix``             — one gossip averaging round  θ_i ← Σ_j w_ij θ_j  (eq. 2's
                        consensus half), used by DSGD / DZSGD.
* ``choco_*``         — ChocoSGD (Koloskova et al., 2019): gossip on *compressed
                        differences* with per-client surrogate copies x̂ and
                        error feedback, top-k sparsification.
* ``topk_compress``   — 99 % top-k sparsifier (the paper's Choco setting).

The communication ledger entries these incur are computed by the dtrain
runner from ``repro.core.messages`` payload formulas.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def mix(stacked: Any, W: np.ndarray) -> Any:
    """θ ← W θ on the client axis: one synchronous gossip round."""
    Wj = jnp.asarray(W, jnp.float32)

    def f(leaf):
        flat = leaf.reshape(leaf.shape[0], -1)
        out = Wj @ flat.astype(jnp.float32)
        return out.astype(leaf.dtype).reshape(leaf.shape)

    return jax.tree.map(f, stacked)


def consensus_error(stacked: Any) -> jax.Array:
    """(1/n) Σ_i ||θ_i − θ̄||² / ||θ̄||² — the consensus-quality metric."""
    def per_leaf(leaf):
        mean = leaf.mean(axis=0, keepdims=True)
        num = jnp.sum((leaf.astype(jnp.float32) - mean.astype(jnp.float32)) ** 2)
        den = jnp.sum(mean.astype(jnp.float32) ** 2) * leaf.shape[0]
        return num, den

    nums_dens = [per_leaf(l) for l in jax.tree.leaves(stacked)]
    num = sum(n for n, _ in nums_dens)
    den = sum(d for _, d in nums_dens)
    return num / jnp.maximum(den, 1e-20)


# ---------------------------------------------------------------------------
# compression operators
# ---------------------------------------------------------------------------

def topk_compress(x: jax.Array, density: float) -> jax.Array:
    """Keep the top ⌈density·d⌉ entries by magnitude, zero the rest.

    Returned dense-with-zeros (the simulator's ledger charges only the sparse
    payload; see messages.topk_payload_bytes).
    """
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * density))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(x.shape).astype(x.dtype)


def tree_topk(tree: Any, density: float) -> Any:
    return jax.tree.map(lambda l: topk_compress(l, density), tree)


# ---------------------------------------------------------------------------
# ChocoSGD state
# ---------------------------------------------------------------------------

class ChocoState(NamedTuple):
    x_hat: Any   # stacked surrogate copies x̂_i   (n, ...)
    # Neighbour surrogates are recovered as W x̂ since every client can track
    # every neighbour's x̂ from the same compressed stream.


def choco_init(stacked_params: Any) -> ChocoState:
    """Paper App. B.2: surrogates initialized *at the pretrained weights*
    (noted as a substantial improvement over zero-init)."""
    return ChocoState(x_hat=jax.tree.map(jnp.copy, stacked_params))


def choco_round(params: Any, state: ChocoState, W: np.ndarray,
                density: float, consensus_lr: float = 1.0,
                active: np.ndarray | None = None):
    """One ChocoSGD communication round.

    q_i = C(x_i − x̂_i)            (compress the innovation)
    x̂_i ← x̂_i + q_i               (all clients update all surrogates)
    x_i ← x_i + γ Σ_j w_ij (x̂_j − x̂_i)

    ``active`` (churn): offline clients transmit no innovation, so their
    surrogate copies stay frozen network-wide; ``W``'s identity rows keep
    their parameters untouched.

    Returns (new_params, new_state, bits_payload_density) — the runner charges
    topk payload bytes for q.
    """
    q = jax.tree.map(lambda x, xh: topk_compress(x - xh, density),
                     params, state.x_hat)
    if active is not None:
        mask = jnp.asarray(active)
        q = jax.tree.map(
            lambda l: jnp.where(mask.reshape((-1,) + (1,) * (l.ndim - 1)),
                                l, jnp.zeros_like(l)), q)
    x_hat = jax.tree.map(jnp.add, state.x_hat, q)

    Wj = jnp.asarray(W, jnp.float32)
    n = Wj.shape[0]
    L = Wj - jnp.eye(n)  # Σ_j w_ij (x̂_j − x̂_i) = (W − I) x̂

    def upd(x, xh):
        flat = xh.reshape(n, -1).astype(jnp.float32)
        corr = (L @ flat).reshape(xh.shape)
        return (x.astype(jnp.float32) + consensus_lr * corr).astype(x.dtype)

    new_params = jax.tree.map(upd, params, x_hat)
    return new_params, ChocoState(x_hat=x_hat)
