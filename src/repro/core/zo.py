"""Zeroth-order estimators (paper §2.2, §3.1).

Two families:

* ``mezo_*``   — MeZO-style dense Gaussian perturbations (the paper's
  baseline, and the oracle that SubCGE's runtime claims are benchmarked
  against in Fig. 5 / Table 4).
* ``two_point_alpha`` — the symmetric two-point directional derivative shared
  by both families (eq. 3/6):  α = (f(θ+εz) − f(θ−εz)) / 2ε.

Memory discipline: like MeZO we never hold θ and θ±εz simultaneously — the
perturbation is applied in place (functionally: θ' = θ + εz, reusing z from
its seed) so peak memory stays at inference level.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import seeds as seedlib


def tree_add_scaled(params: Any, z: Any, scale) -> Any:
    return jax.tree.map(lambda p, zz: p + jnp.asarray(scale, p.dtype) * zz.astype(p.dtype),
                        params, z)


def mezo_z(params: Any, message_seed, frozen: Callable[[str], bool] | None = None) -> Any:
    """Dense Gaussian perturbation reconstructed from a message seed."""
    key = seedlib.message_key(message_seed)

    def visit(path: str, leaf: jax.Array):
        if frozen is not None and frozen(path):
            return jnp.zeros_like(leaf)
        return seedlib.gaussian_like(seedlib.leaf_key(key, path), leaf.shape,
                                     jnp.float32).astype(leaf.dtype)

    return seedlib.map_with_paths(visit, params)


def two_point_alpha(loss_fn: Callable[[Any], jax.Array], params: Any, z: Any,
                    eps: float) -> jax.Array:
    """α = (f(θ+εz) − f(θ−εz)) / 2ε  — the scalar that travels in a message."""
    lp = loss_fn(tree_add_scaled(params, z, eps))
    lm = loss_fn(tree_add_scaled(params, z, -eps))
    return (lp - lm) / (2.0 * eps)


def mezo_alpha(loss_fn, params, message_seed, eps,
               frozen: Callable[[str], bool] | None = None) -> jax.Array:
    return two_point_alpha(loss_fn, params, mezo_z(params, message_seed, frozen), eps)


def mezo_apply_messages(params: Any, message_seeds: jax.Array,
                        coefs: jax.Array,
                        frozen: Callable[[str], bool] | None = None) -> Any:
    """Replay K dense messages: θ ← θ + Σ_k coef_k · N(seed_k).

    O(K·d) memory-bound axpy stream — this is precisely the cost SubCGE
    removes (Fig. 5); kept as the reference implementation and benchmark
    baseline.
    """
    def body(p, sc):
        s, c = sc
        z = mezo_z(p, s, frozen)
        return tree_add_scaled(p, z, c), None

    out, _ = jax.lax.scan(body, params, (message_seeds, coefs))
    return out


def zo_sgd_step(loss_fn, params, step_seed, eps, lr):
    """Single-client ZO-SGD (eq. 4): baseline optimizer for tests."""
    z = mezo_z(params, step_seed)
    alpha = two_point_alpha(loss_fn, params, z, eps)
    return tree_add_scaled(params, z, -lr * alpha), alpha
