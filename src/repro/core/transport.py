"""Transport plugins: the communication half of the Method × Transport API.

A transport owns (a) the network substrate — flood engine, mixing matrix,
or nothing — (b) the churn response (anti-entropy drains, live-subgraph
reweighting), and (c) the :class:`~repro.core.messages.CommLedger`.  Byte
accounting lives HERE and nowhere else: a method never sees the ledger, so
the paper's cost metric cannot drift when methods are added or refactored.

Three substrates cover every §4.2 protocol:

* :class:`FloodTransport`   — seed–scalar flooding (``core.flood``) with
  delayed-flooding ``k``-hop budgets, anti-entropy catch-up after churn,
  and end-of-run drain.  Inboxes are :class:`FloodInbox` padded matrices.
* :class:`GossipTransport`  — mixing-matrix parameter exchange every
  ``every`` steps, optionally through Choco compressed differences.  The
  inbox is the mixed trainable pytree.
* :class:`GossipSRTransport`— the §3.2 strawman: full seed–scalar histories
  across every edge, averaged under the mixing matrix.
* :class:`NullTransport`    — no communication (the centralized oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import numpy as np

from repro.core import flood, gossip, messages
from repro.core.messages import CommLedger, MESSAGE_BYTES
from repro.topology import graphs
from repro.topology.dynamic import DynamicTopology


@dataclasses.dataclass
class FloodInbox:
    """One step's newly delivered flood payloads: dense padded ``(n, K)``
    seed/coef/step matrices (see ``flood.pad_payloads``) plus the receiver
    step ``t`` (only the legacy ``epoch_replay=False`` path reads it)."""
    seeds: np.ndarray
    coefs: np.ndarray
    steps: np.ndarray
    t: int


class TransportBase:
    """Default hooks so concrete transports only override what they use."""

    ledger: CommLedger

    def bind(self, init_payload: Any) -> None:
        pass

    def apply_churn(self, events) -> None:
        raise ValueError(f"{type(self).__name__} does not support churn")

    def drain(self, max_iters: int, final_step: int) -> Iterator[Any]:
        return iter(())

    def stats(self) -> dict:
        return {}

    # -- checkpointing --------------------------------------------------------

    def state_arrays(self) -> dict | None:
        """Array-valued pytree of transport state (None when stateless)."""
        return None

    def state_meta(self) -> dict:
        return {"ledger": dataclasses.asdict(self.ledger)}

    def load_state(self, arrays: Any, meta: dict) -> None:
        for k, v in meta.get("ledger", {}).items():
            setattr(self.ledger, k, int(v))


class FloodTransport(TransportBase):
    """Seed–scalar flooding over a (churnable) overlay graph.

    Wraps ``flood.make_network``: per-step exchange injects the outbox
    messages and runs ``k`` flood rounds (``flood_k`` or the live effective
    diameter), prepending any anti-entropy catch-up payloads produced by
    churn earlier in the step so they ride in the same padded matrices.
    """

    def __init__(self, graph, *, backend: str = "auto",
                 flood_k: int | None = None):
        self.net = flood.make_network(graph, backend=backend)
        self.flood_k = flood_k
        self._pending = None          # anti-entropy catch-up, per-client arrays

    @property
    def ledger(self) -> CommLedger:
        return self.net.ledger

    def active_mask(self) -> np.ndarray:
        return self.net.active_mask()

    def apply_churn(self, events) -> None:
        self.net.apply_churn(events)
        self._pending = self.net.drain_catchup_arrays()

    def exchange(self, payload, t: int, active: np.ndarray) -> FloodInbox:
        for i, msg in payload:
            self.net.inject(i, msg)
        # full flooding tracks the *effective* diameter, which churn moves
        k_hops = self.flood_k if self.flood_k is not None else self.net.diameter
        sds, cfs, stp = self.net.rounds_padded(k_hops, extra=self._pending)
        self._pending = None
        return FloodInbox(sds, cfs, stp, t)

    def drain(self, max_iters: int, final_step: int) -> Iterator[FloodInbox]:
        """Flush in-flight delayed-flooding messages: flood with no new
        injections until the network is quiescent, so every sent message is
        delivered (and, with epoch replay, consensus restored)."""
        for _ in range(max_iters):
            if self.net.in_flight() == 0:
                break
            sds, cfs, stp = self.net.rounds_padded(self.net.diameter + 1)
            yield FloodInbox(sds, cfs, stp, final_step)

    def stats(self) -> dict:
        return {"n_messages": self.ledger.n_messages,
                "diameter": self.net.diameter,
                "sync_bytes": self.ledger.sync_bytes,
                "n_syncs": self.ledger.n_syncs}

    # serializing the network builds the full message-table/seen-set dump;
    # the Trainer calls state_arrays then state_meta per checkpoint, so the
    # first call stashes the (arrays, meta) pair for the second.

    def state_arrays(self):
        arrays, self._ck_meta = self.net.state_dict()
        return arrays

    def state_meta(self) -> dict:
        net_meta = getattr(self, "_ck_meta", None)
        if net_meta is None:
            net_meta = self.net.state_dict()[1]
        self._ck_meta = None
        return {**super().state_meta(), "net": net_meta}

    def load_state(self, arrays, meta) -> None:
        super().load_state(arrays, meta)
        self.net.load_state_dict(arrays, meta["net"])
        self._pending = None


class GossipTransport(TransportBase):
    """Mixing-matrix parameter exchange, optionally Choco-compressed.

    ``exchange`` fires every ``every`` steps (``local_iters``) and returns
    the mixed trainable pytree; other steps return None.  Under churn the
    mixing matrix shrinks to the live subgraph (frozen rows become e_i) and
    only live edges are charged.  With ``choco_density`` set, differences
    are top-k compressed through per-client surrogate copies whose state
    lives here (it is communication state, not method state).
    """

    def __init__(self, graph, W: np.ndarray, *, every: int,
                 choco_density: float | None = None,
                 churn_aware: bool = False):
        self.topo = DynamicTopology(graph)
        self.W = W
        self.every = every
        self.density = choco_density
        self.churn_aware = churn_aware
        self.live_edges = graph.number_of_edges()
        self.ledger = CommLedger(n_edges=graph.number_of_edges())
        self._choco = None

    def bind(self, init_payload) -> None:
        if self.density is not None:
            # paper App. B.2: surrogates start at the pretrained weights
            self._choco = gossip.choco_init(init_payload)

    def active_mask(self) -> np.ndarray:
        return self.topo.active_mask()

    def apply_churn(self, events) -> None:
        # gossip has no anti-entropy — the mixing matrix just shrinks
        self.topo.apply_events(events)
        self.W = graphs.metropolis_weights(self.topo.current_graph())
        self.live_edges = self.topo.live_edge_count()

    def exchange(self, trainable, t: int, active: np.ndarray):
        if (t + 1) % self.every != 0:
            return None
        n = self.topo.n
        floats_per_client = sum(l.size for l in jax.tree.leaves(trainable)) // n
        if self.density is not None:
            # mask offline clients' innovations whenever anyone is actually
            # offline, churn_aware or not — a directly composed transport
            # whose flag disagrees with the method still masks correctly
            # (with every client online the mask is a bitwise no-op)
            use_active = self.churn_aware or not active.all()
            trainable, self._choco = gossip.choco_round(
                trainable, self._choco, self.W, self.density,
                active=active if use_active else None)
            self.ledger.send(2 * self.live_edges * messages.topk_payload_bytes(
                floats_per_client, self.density))
        else:
            trainable = gossip.mix(trainable, self.W)
            self.ledger.send(2 * self.live_edges * floats_per_client * 4)
        return trainable

    def state_arrays(self):
        return {"x_hat": self._choco.x_hat} if self._choco is not None else None

    def state_meta(self) -> dict:
        return {**super().state_meta(),
                "topo": self.topo.state_dict(),
                "live_edges": self.live_edges,
                "W": np.asarray(self.W, np.float64).tolist()}

    def load_state(self, arrays, meta) -> None:
        super().load_state(arrays, meta)
        self.topo.load_state_dict(meta["topo"])
        self.live_edges = int(meta["live_edges"])
        self.W = np.asarray(meta["W"], np.float64)
        if self.density is not None:
            x = (arrays or {}).get("x_hat")
            if x is None:
                raise ValueError("choco checkpoint is missing the surrogate "
                                 "copies (x_hat)")
            self._choco = gossip.ChocoState(
                x_hat=jax.tree.map(lambda l: jax.numpy.asarray(l), x))


class GossipSRTransport(TransportBase):
    """Gossip with shared randomness (§3.2 strawman): every ``every`` steps
    each client ships its FULL coefficient history to every neighbour —
    O(t·n) bytes per edge — and histories are averaged under the mixing
    matrix (eq. 8)."""

    def __init__(self, graph, W: np.ndarray, *, every: int):
        self.W = W
        self.every = every
        self.neigh = graphs.neighbors(graph)
        self.n = graph.number_of_nodes()
        self.ledger = CommLedger(n_edges=graph.number_of_edges())

    def active_mask(self) -> np.ndarray:
        return np.ones(self.n, dtype=bool)

    def exchange(self, hist: list[dict], t: int, active: np.ndarray):
        if (t + 1) % self.every != 0:
            return None
        n, W = self.n, self.W
        all_uids = set()
        for i in range(n):
            all_uids |= set(hist[i].keys())
        for i in range(n):
            for j in self.neigh[i]:
                self.ledger.send(len(hist[j]) * MESSAGE_BYTES,
                                 count=len(hist[j]))
        new_hist = []
        for i in range(n):
            h = {}
            # uid order decides delta-replay float order downstream.  uids
            # are (client, step) int tuples: CPython hashes them unsalted,
            # so iteration order is identical on every run/machine given
            # the same insertion history — and the golden-parity suite pins
            # exactly this order; sorted() would diverge from the frozen
            # monolith oracle bit-for-bit.
            for uid in all_uids:  # sfcheck: noqa[SF003] -- int-tuple uids hash unsalted; order is deterministic and bitwise-pinned by test_golden_parity

                cbar = sum(W[i, j] * hist[j].get(uid, [0, 0, 0.0])[2]
                           for j in range(n) if W[i, j] > 0)
                ref = next(hist[j][uid] for j in range(n) if uid in hist[j])
                h[uid] = [ref[0], ref[1], cbar]
            new_hist.append(h)
        return new_hist


class NullTransport(TransportBase):
    """No communication (the centralized equivalence oracle)."""

    def __init__(self, n: int):
        self.n = n
        self.ledger = CommLedger()

    def active_mask(self) -> np.ndarray:
        return np.ones(self.n, dtype=bool)

    def exchange(self, payload, t: int, active: np.ndarray):
        return None
