"""Flooding as a consensus primitive (paper §3.3, Algorithm 1 block (C)).

Faithful implementation of the recursive flood: upon *first* receipt of a
message, a client forwards it to all neighbours next round; duplicates are
filtered against the seen-set ``S_i``.  After ``diameter(G)`` rounds every
message injected at the start has reached every client exactly once, with its
coefficient untouched — the property that distinguishes flooding from gossip.

The same machinery implements **delayed flooding** (paper §4.5): run only
``k`` rounds per local iteration and let the frontier sets ``R_i`` carry over
to the next iteration, bounding staleness by ⌈D/k⌉.

This module is deliberately pure-Python + networkx: it is the *protocol*
layer of the simulator, where per-message bookkeeping is the whole point.
The pod runtime (repro/launch) maps the end-to-end effect of a full flood
onto a single all-gather instead (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import networkx as nx

from repro.core.messages import Message, CommLedger, MESSAGE_BYTES


@dataclasses.dataclass
class ClientFloodState:
    seen: set            # S_i — uids of every message ever accepted
    frontier: list       # R_i — messages to forward on the next round

    @classmethod
    def empty(cls) -> "ClientFloodState":
        return cls(seen=set(), frontier=[])


class FloodNetwork:
    """Message-passing state for one decentralized run."""

    def __init__(self, graph: nx.Graph):
        if not nx.is_connected(graph):
            raise ValueError("SeedFlood assumes a connected communication graph")
        self.graph = graph
        self.n = graph.number_of_nodes()
        self.neighbors = [sorted(graph.neighbors(i)) for i in range(self.n)]
        self.diameter = nx.diameter(graph)
        self.states = [ClientFloodState.empty() for _ in range(self.n)]
        self.ledger = CommLedger(n_edges=graph.number_of_edges())

    # -- Algorithm 1: R_i = R_i ∪ {(s_{i,t}, η α / n)} ------------------------
    def inject(self, client: int, msg: Message) -> None:
        """A client's freshly generated update enters its own frontier (it has
        already applied it locally — Algorithm 1 applies the local update in
        block (B) and floods it in block (C))."""
        st = self.states[client]
        if msg.uid in st.seen:
            raise ValueError(f"duplicate injection of {msg.uid}")
        st.seen.add(msg.uid)
        st.frontier.append(msg)

    # -- one synchronous flood round ------------------------------------------
    def round(self) -> list[list[Message]]:
        """All clients simultaneously send their frontier to every neighbour.

        Returns, per client, the list of *newly accepted* messages this round
        (already deduplicated against S_i) — the runner applies exactly these,
        each exactly once, which is the fixed-coefficient property.
        """
        inboxes: list[list[Message]] = [[] for _ in range(self.n)]
        for i in range(self.n):
            st = self.states[i]
            if not st.frontier:
                continue
            payload = len(st.frontier) * MESSAGE_BYTES
            for j in self.neighbors[i]:
                inboxes[j].extend(st.frontier)
                self.ledger.send(payload, count=len(st.frontier))
            st.frontier = []

        fresh: list[list[Message]] = [[] for _ in range(self.n)]
        for i in range(self.n):
            st = self.states[i]
            for msg in inboxes[i]:
                if msg.uid in st.seen:
                    continue  # R_i = R_i \ S_i
                st.seen.add(msg.uid)  # S_i = R_i ∪ S_i
                st.frontier.append(msg)
                fresh[i].append(msg)
        self.ledger.rounds += 1
        return fresh

    def rounds(self, k: int) -> list[list[Message]]:
        """Run k flood rounds; returns per-client newly accepted messages
        aggregated over the k rounds (what a local iteration applies)."""
        fresh: list[list[Message]] = [[] for _ in range(self.n)]
        for _ in range(k):
            if all(not st.frontier for st in self.states):
                break  # quiescent — nothing in flight anywhere
            got = self.round()
            for i in range(self.n):
                fresh[i].extend(got[i])
        return fresh

    def full_flood(self) -> list[list[Message]]:
        """Flood until quiescent (≥ diameter rounds suffice for synchronous
        injection; carried-over frontiers may need fewer)."""
        return self.rounds(self.diameter + 1)

    # -- introspection ---------------------------------------------------------
    def in_flight(self) -> int:
        return sum(len(st.frontier) for st in self.states)

    def coverage(self, uid) -> int:
        """How many clients have accepted message ``uid`` (tests)."""
        return sum(uid in st.seen for st in self.states)


def staleness_bound(diameter: int, k: int) -> int:
    """Paper §4.5: delayed flooding with k hops/iteration bounds message
    staleness by ⌈D/k⌉ iterations."""
    return -(-diameter // k)


def flood_bytes_per_iteration(graph: nx.Graph, n_new_messages: int) -> int:
    """Upper bound on bytes a full flood of ``n_new_messages`` costs: each
    message traverses each *directed* edge at most once."""
    return 2 * graph.number_of_edges() * n_new_messages * MESSAGE_BYTES


def gossip_sr_history_bytes(t: int, n: int, graph: nx.Graph) -> int:
    """Gossip-with-shared-randomness (paper §3.2): at iteration t each edge
    carries the O(t·n) full history of seed–scalar pairs."""
    return 2 * graph.number_of_edges() * t * n * MESSAGE_BYTES
