"""Flooding as a consensus primitive (paper §3.3, Algorithm 1 block (C)).

Faithful implementation of the recursive flood: upon *first* receipt of a
message, a client forwards it to all neighbours next round; duplicates are
filtered against the seen-set ``S_i``.  After ``diameter(G)`` rounds every
message injected at the start has reached every client exactly once, with its
coefficient untouched — the property that distinguishes flooding from gossip.

The same machinery implements **delayed flooding** (paper §4.5): run only
``k`` rounds per local iteration and let the frontier sets ``R_i`` carry over
to the next iteration, bounding staleness by ⌈D/k⌉.

Beyond the paper, the network is **churn-tolerant** (DESIGN.md §6): topology
is mutable mid-run via ``repro.topology.dynamic`` — nodes leave (dropping
their frontiers) and rejoin, links fail and recover, partitions open and
heal.  Recovery is an *anti-entropy* sync: across every edge a rejoin or
link-restore revives, the two endpoints exchange seen-set digests and
re-send exactly the seed-scalar messages the other side missed.  Re-sent
messages enter the receiver's frontier and re-flood outward; duplicates are
filtered by ``S_i``, so coefficients still arrive exactly once and unchanged
— churn never breaks the fixed-coefficient property.

Two engines implement the same protocol:

* ``FloodNetwork``       — the pure-Python reference, where per-message
  bookkeeping is the whole point (readable, property-tested).
* ``VectorFloodNetwork`` — a numpy *bitset* engine: seen/frontier sets are
  packed bit matrices, a flood round is a handful of vectorized OR/AND-NOT
  ops, and newly accepted messages come back as index arrays.  This is what
  makes n=256-client meshgrid sweeps tractable (≳10× over the reference).

The pod runtime (repro/launch) maps the end-to-end effect of a full flood
onto a single all-gather instead (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import networkx as nx
import numpy as np

from repro.core.messages import (Message, CommLedger, MESSAGE_BYTES,
                                 digest_bytes, pad_pow2)
from repro.topology.dynamic import ChurnEvent, DynamicTopology


#: ``make_network(backend="auto")`` switches to the bitset engine at this size.
AUTO_VECTOR_MIN_CLIENTS = 64

#: Sender-step value marking padding columns in dense payload matrices.
#: Negative on purpose: no real refresh step is negative, so padded entries
#: can never alias a live subspace epoch (their coefficient is 0 anyway).
STEP_PAD = -1


def pad_payloads(payloads: list[tuple[np.ndarray, np.ndarray, np.ndarray]],
                 minimum: int = 4):
    """Stack per-client ragged ``(seeds, coefs, steps)`` payloads into dense
    ``(n, K)`` matrices with K pow2-bucketed — the batched-jit wire format.

    Padding columns are ``(seed=0, coef=0, step=STEP_PAD)``: a zero
    coefficient makes the message an exact no-op under SubCGE (zero scatter
    into A, zero Gaussian axpy), so consumers never need a length mask.
    Returns ``(n, 0)`` matrices when no client received anything.
    """
    n = len(payloads)
    kmax = max((len(p[0]) for p in payloads), default=0)
    if kmax == 0:
        return (np.zeros((n, 0), np.uint32), np.zeros((n, 0), np.float32),
                np.full((n, 0), STEP_PAD, np.int32))
    K = pad_pow2(kmax, minimum)
    seeds = np.zeros((n, K), np.uint32)
    coefs = np.zeros((n, K), np.float32)
    steps = np.full((n, K), STEP_PAD, np.int32)
    for i, (sd, cf, st) in enumerate(payloads):
        k = len(sd)
        seeds[i, :k] = sd
        coefs[i, :k] = cf
        steps[i, :k] = st
    return seeds, coefs, steps


@dataclasses.dataclass
class ClientFloodState:
    seen: set            # S_i — uids of every message ever accepted
    frontier: list       # R_i — messages to forward on the next round
    store: dict          # uid -> Message, for anti-entropy re-send

    @classmethod
    def empty(cls) -> "ClientFloodState":
        return cls(seen=set(), frontier=[], store={})


@dataclasses.dataclass
class SyncReport:
    """Anti-entropy accounting for one ``apply_churn`` call."""
    syncs: int = 0            # pairwise digest exchanges performed
    transferred: int = 0      # messages re-sent to close the set difference


def _as_topology(graph) -> DynamicTopology:
    if isinstance(graph, DynamicTopology):
        return graph
    return DynamicTopology(graph)


class _FloodBase:
    """Topology plumbing + churn entry point shared by both engines."""

    def __init__(self, graph):
        self.topo = _as_topology(graph)
        self.graph = self.topo.base_graph
        self.n = self.topo.n
        self.ledger = CommLedger(n_edges=self.graph.number_of_edges())

    @property
    def neighbors(self) -> list[list[int]]:
        return self.topo.neighbors()

    @property
    def diameter(self) -> int:
        """Effective diameter of the *current* topology (max over live
        components) — the flood-rounds budget for full coverage."""
        return max(self.topo.effective_diameter(), 1)

    def active_mask(self) -> np.ndarray:
        return self.topo.active_mask()

    # -- churn ----------------------------------------------------------------

    def apply_churn(self, events: Iterable[ChurnEvent]) -> SyncReport:
        """Apply topology mutations; departed nodes drop their frontiers,
        rejoined nodes and restored links run anti-entropy.

        A rejoin syncs across *every* revived live edge, not just one
        neighbour: if the departure had cut the surviving graph, the
        rejoining node is the bridge, and each of its edges may face a
        different component whose messages the others never saw.
        """
        delta = self.topo.apply_events(events)
        report = SyncReport()
        for i in delta.left:
            self._drop_frontier(i)
        synced: set[frozenset] = set()
        neighbors = self.topo.neighbors()
        for i, _ in delta.joined:
            for j in neighbors[i]:
                if frozenset((i, j)) not in synced:
                    synced.add(frozenset((i, j)))
                    self._anti_entropy(i, j, report)
        for u, v in delta.restored:
            if self.topo.is_active(u) and self.topo.is_active(v) \
                    and frozenset((u, v)) not in synced:
                synced.add(frozenset((u, v)))
                self._anti_entropy(u, v, report)
        return report

    def drain_catchup(self) -> list[list[Message]]:
        """Messages each client gained via anti-entropy since the last drain
        (the runner applies these like freshly flooded messages)."""
        out = self._catchup
        self._catchup = [[] for _ in range(self.n)]
        return out

    def drain_catchup_arrays(self) \
            -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """:meth:`drain_catchup` in the runner's payload format: per-client
        ``(seeds, coefs, steps)`` arrays, sender steps included so catch-up
        replays under the right subspace epoch."""
        return [(np.asarray([m.seed for m in f], np.uint32),
                 np.asarray([m.coef for m in f], np.float32),
                 np.asarray([m.step for m in f], np.int32))
                for f in self.drain_catchup()]

    def rounds_padded(self, k: int, extra=None, minimum: int = 4):
        """Run k flood rounds and return dense padded ``(n, K)`` seed/coef/
        step matrices (see :func:`pad_payloads`) — the single-dispatch input
        of the batched jit replay.  ``extra`` optionally prepends per-client
        ``(seeds, coefs, steps)`` payloads (anti-entropy catch-up) so they
        ride in the same matrices."""
        payloads = self.rounds_arrays(k)
        if extra is not None:
            payloads = [tuple(np.concatenate([np.asarray(e, p.dtype), p])
                              for e, p in zip(ex, pl))
                        for ex, pl in zip(extra, payloads)]
        return pad_payloads(payloads, minimum)

    # engine hooks
    def _drop_frontier(self, i: int) -> None:
        raise NotImplementedError

    def _anti_entropy(self, a: int, b: int, report: SyncReport) -> None:
        raise NotImplementedError

    # -- checkpointing ---------------------------------------------------------
    #
    # A flood network is run state: in-flight frontiers (delayed flooding),
    # seen-sets (dedup), the message table, pending anti-entropy catch-up and
    # the topology overlay all shape future rounds and byte accounting, so a
    # bitwise resume must capture them.  ``state_dict`` returns
    # ``(arrays, meta)``: an array-valued pytree for the .npz side of a
    # checkpoint and a JSON-serializable dict for its metadata.  Frontier and
    # catch-up index arrays are ORDERED — forwarding order determines payload
    # order, which determines float-summation order downstream.

    def _messages_arrays(self, msgs: list[Message]) -> dict:
        return {
            "seed": np.asarray([m.seed for m in msgs], np.int64),
            "coef": np.asarray([m.coef for m in msgs], np.float64),
            "origin": np.asarray([m.origin for m in msgs], np.int64),
            "step": np.asarray([m.step for m in msgs], np.int64),
        }

    @staticmethod
    def _messages_from_arrays(m: dict) -> list[Message]:
        return [Message(seed=int(s), coef=float(c), origin=int(o), step=int(t))
                for s, c, o, t in zip(np.asarray(m["seed"]),
                                      np.asarray(m["coef"]),
                                      np.asarray(m["origin"]),
                                      np.asarray(m["step"]))]

    def state_dict(self) -> tuple[dict, dict]:
        raise NotImplementedError

    def load_state_dict(self, arrays: dict, meta: dict) -> None:
        raise NotImplementedError


class FloodNetwork(_FloodBase):
    """Reference per-message engine for one decentralized run."""

    def __init__(self, graph):
        super().__init__(graph)
        self.states = [ClientFloodState.empty() for _ in range(self.n)]
        self._catchup: list[list[Message]] = [[] for _ in range(self.n)]

    # -- Algorithm 1: R_i = R_i ∪ {(s_{i,t}, η α / n)} ------------------------
    def inject(self, client: int, msg: Message) -> None:
        """A client's freshly generated update enters its own frontier (it has
        already applied it locally — Algorithm 1 applies the local update in
        block (B) and floods it in block (C))."""
        if not self.topo.is_active(client):
            raise ValueError(f"client {client} is offline")
        st = self.states[client]
        if msg.uid in st.seen:
            raise ValueError(f"duplicate injection of {msg.uid}")
        st.seen.add(msg.uid)
        st.store[msg.uid] = msg
        st.frontier.append(msg)

    # -- one synchronous flood round ------------------------------------------
    def round(self) -> list[list[Message]]:
        """All clients simultaneously send their frontier to every neighbour.

        Returns, per client, the list of *newly accepted* messages this round
        (already deduplicated against S_i) — the runner applies exactly these,
        each exactly once, which is the fixed-coefficient property.
        """
        neighbors = self.neighbors
        inboxes: list[list[Message]] = [[] for _ in range(self.n)]
        for i in range(self.n):
            st = self.states[i]
            if not st.frontier:
                continue
            payload = len(st.frontier) * MESSAGE_BYTES
            for j in neighbors[i]:
                inboxes[j].extend(st.frontier)
                self.ledger.send(payload, count=len(st.frontier))
            st.frontier = []

        fresh: list[list[Message]] = [[] for _ in range(self.n)]
        for i in range(self.n):
            st = self.states[i]
            for msg in inboxes[i]:
                if msg.uid in st.seen:
                    continue  # R_i = R_i \ S_i
                st.seen.add(msg.uid)  # S_i = R_i ∪ S_i
                st.store[msg.uid] = msg
                st.frontier.append(msg)
                fresh[i].append(msg)
        self.ledger.rounds += 1
        return fresh

    def rounds(self, k: int) -> list[list[Message]]:
        """Run k flood rounds; returns per-client newly accepted messages
        aggregated over the k rounds (what a local iteration applies)."""
        fresh: list[list[Message]] = [[] for _ in range(self.n)]
        for _ in range(k):
            if all(not st.frontier for st in self.states):
                break  # quiescent — nothing in flight anywhere
            got = self.round()
            for i in range(self.n):
                fresh[i].extend(got[i])
        return fresh

    def rounds_arrays(self, k: int) \
            -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Like :meth:`rounds` but returns per-client (seeds, coefs, steps)
        arrays — the payload shape the training runner consumes.  Sender
        steps travel with the message so the receiver can replay under the
        *sender's* subspace epoch."""
        fresh = self.rounds(k)
        return [(np.asarray([m.seed for m in f], np.uint32),
                 np.asarray([m.coef for m in f], np.float32),
                 np.asarray([m.step for m in f], np.int32)) for f in fresh]

    def full_flood(self) -> list[list[Message]]:
        """Flood until quiescent (≥ diameter rounds suffice for synchronous
        injection; carried-over frontiers may need fewer)."""
        return self.rounds(self.diameter + 1)

    # -- churn hooks -----------------------------------------------------------
    def _drop_frontier(self, i: int) -> None:
        self.states[i].frontier = []

    def _anti_entropy(self, a: int, b: int, report: SyncReport) -> None:
        """Symmetric digest exchange across one live edge: each side re-sends
        the seed-scalar messages the other is missing.  Re-sent messages join
        the receiver's frontier and re-flood outward (duplicates filtered by
        S_i), so a single sync repairs the whole component."""
        sa, sb = self.states[a], self.states[b]
        payload = digest_bytes(len(sa.seen)) + digest_bytes(len(sb.seen))
        moved = 0
        for dst, dst_state, src_state in ((a, sa, sb), (b, sb, sa)):
            missed = sorted(src_state.seen - dst_state.seen)
            for uid in missed:
                msg = src_state.store[uid]
                dst_state.seen.add(uid)
                dst_state.store[uid] = msg
                dst_state.frontier.append(msg)
                self._catchup[dst].append(msg)
            moved += len(missed)
        self.ledger.sync(payload + moved * MESSAGE_BYTES, count=moved)
        report.syncs += 1
        report.transferred += moved

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> tuple[dict, dict]:
        union: dict = {}
        for st in self.states:
            union.update(st.store)
        uids = sorted(union)
        idx = {uid: k for k, uid in enumerate(uids)}
        arrays: dict = {"msgs": self._messages_arrays([union[u] for u in uids])}
        for i, st in enumerate(self.states):
            arrays[f"seen{i}"] = np.asarray(
                sorted(idx[u] for u in st.seen), np.int64)
            arrays[f"frontier{i}"] = np.asarray(
                [idx[m.uid] for m in st.frontier], np.int64)
            arrays[f"catchup{i}"] = np.asarray(
                [idx[m.uid] for m in self._catchup[i]], np.int64)
        return arrays, {"engine": "python", "topo": self.topo.state_dict()}

    def load_state_dict(self, arrays: dict, meta: dict) -> None:
        self.topo.load_state_dict(meta["topo"])
        msgs = self._messages_from_arrays(arrays["msgs"])
        self.states = [ClientFloodState.empty() for _ in range(self.n)]
        self._catchup = [[] for _ in range(self.n)]
        for i, st in enumerate(self.states):
            for k in np.asarray(arrays[f"seen{i}"], np.int64):
                m = msgs[int(k)]
                st.seen.add(m.uid)
                st.store[m.uid] = m
            st.frontier = [msgs[int(k)]
                           for k in np.asarray(arrays[f"frontier{i}"], np.int64)]
            self._catchup[i] = [msgs[int(k)]
                                for k in np.asarray(arrays[f"catchup{i}"],
                                                    np.int64)]

    # -- introspection ---------------------------------------------------------
    def in_flight(self) -> int:
        return sum(len(st.frontier) for st in self.states)

    def coverage(self, uid) -> int:
        """How many clients have accepted message ``uid`` (tests)."""
        return sum(uid in st.seen for st in self.states)

    def seen_uids(self, i: int) -> set:
        return set(self.states[i].seen)


class VectorFloodNetwork(_FloodBase):
    """Bitset engine: identical protocol, vectorized state.

    Messages live in an append-only table (parallel ``seeds``/``coefs``/
    ``steps`` numpy arrays); each client's ``S_i`` and ``R_i`` are rows of packed
    uint8 bit matrices.  One flood round is: per receiver, OR the frontier
    rows of its live neighbours, then ``fresh = inbox & ~seen``;
    ``seen |= fresh``; ``frontier = fresh``.  Ledger counts come from
    ``np.bitwise_count`` popcounts, so byte accounting matches the
    reference engine bit-for-bit.
    """

    _INITIAL_BITS = 512

    def __init__(self, graph):
        super().__init__(graph)
        self._msgs: list[Message] = []
        self._uid2idx: dict = {}
        self._seeds = np.zeros(self._INITIAL_BITS, np.uint32)
        self._coefs = np.zeros(self._INITIAL_BITS, np.float32)
        self._steps = np.full(self._INITIAL_BITS, STEP_PAD, np.int32)
        nbytes = self._INITIAL_BITS // 8
        self._seen = np.zeros((self.n, nbytes), np.uint8)
        self._front = np.zeros((self.n, nbytes), np.uint8)
        self._catchup: list[list[Message]] = [[] for _ in range(self.n)]
        self._adj_version = -1
        self._adj: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None

    # -- message table ---------------------------------------------------------
    def _register(self, msg: Message) -> int:
        idx = len(self._msgs)
        if idx >= self._seeds.shape[0]:
            grow = self._seeds.shape[0]
            self._seeds = np.concatenate([self._seeds, np.zeros(grow, np.uint32)])
            self._coefs = np.concatenate([self._coefs, np.zeros(grow, np.float32)])
            self._steps = np.concatenate(
                [self._steps, np.full(grow, STEP_PAD, np.int32)])
            pad = np.zeros((self.n, grow // 8), np.uint8)
            self._seen = np.concatenate([self._seen, pad], axis=1)
            self._front = np.concatenate([self._front, pad], axis=1)
        self._msgs.append(msg)
        self._uid2idx[msg.uid] = idx
        self._seeds[idx] = msg.seed
        self._coefs[idx] = msg.coef
        self._steps[idx] = msg.step
        return idx

    @staticmethod
    def _set_bit(mat: np.ndarray, row: int, idx: int) -> None:
        mat[row, idx >> 3] |= np.uint8(1 << (idx & 7))

    @staticmethod
    def _get_bit(mat: np.ndarray, row: int, idx: int) -> bool:
        return bool(mat[row, idx >> 3] & (1 << (idx & 7)))

    def _occ_bytes(self) -> int:
        """Bytes of the bit rows actually occupied by registered messages —
        capacity grows geometrically, so unpacking full rows would be
        O(capacity) per call regardless of how few messages exist."""
        return (len(self._msgs) + 7) >> 3

    def _row_indices(self, bits: np.ndarray) -> np.ndarray:
        occ = self._occ_bytes()
        return np.flatnonzero(
            np.unpackbits(bits[:occ], bitorder="little")[:len(self._msgs)])

    def _rows_indices(self, bits: np.ndarray) -> list[np.ndarray]:
        """Per-row set indices for a whole (n, nbytes) bit matrix with ONE
        unpackbits call over the occupied prefix (the per-row variant costs
        n separate unpacks)."""
        occ = self._occ_bytes()
        if occ == 0:
            return [np.zeros(0, np.int64)] * bits.shape[0]
        unpacked = np.unpackbits(bits[:, :occ], axis=1,
                                 bitorder="little")[:, :len(self._msgs)]
        return [np.flatnonzero(row) for row in unpacked]

    # -- protocol --------------------------------------------------------------
    def inject(self, client: int, msg: Message) -> None:
        if not self.topo.is_active(client):
            raise ValueError(f"client {client} is offline")
        if msg.uid in self._uid2idx and self._get_bit(
                self._seen, client, self._uid2idx[msg.uid]):
            raise ValueError(f"duplicate injection of {msg.uid}")
        idx = self._uid2idx.get(msg.uid)
        if idx is None:
            idx = self._register(msg)
        self._set_bit(self._seen, client, idx)
        self._set_bit(self._front, client, idx)

    def _flat_adjacency(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(degrees, flat neighbour ids, per-node segment starts) — the
        reduceat layout for one vectorized OR-gather per round.  Rebuilt only
        when the topology version changes."""
        if self._adj_version != self.topo.version:
            nbrs = self.neighbors
            deg = np.array([len(ns) for ns in nbrs], np.int64)
            src = (np.concatenate([np.asarray(ns, np.int64)
                                   for ns in nbrs if ns])
                   if deg.sum() else np.zeros(0, np.int64))
            seg = np.zeros(self.n, np.int64)
            np.cumsum(deg[:-1], out=seg[1:])
            self._adj = (deg, src, seg)
            self._adj_version = self.topo.version
        return self._adj

    def _round_bits(self) -> np.ndarray:
        """One synchronous round on the bit matrices; returns fresh bits."""
        deg, src, seg = self._flat_adjacency()
        counts = np.bitwise_count(self._front).sum(axis=1, dtype=np.int64)
        sent = int((counts * deg).sum())
        if sent:
            self.ledger.send(sent * MESSAGE_BYTES, count=sent)
        if src.size:
            # inbox[i] = OR of neighbours' frontiers; reduceat over the
            # flattened neighbour rows does every segment in one C call.
            # Zero-degree segments alias a neighbouring row — masked below.
            inbox = np.bitwise_or.reduceat(
                self._front[src], np.minimum(seg, src.size - 1), axis=0)
            inbox[deg == 0] = 0
        else:
            inbox = np.zeros_like(self._front)
        fresh = inbox & ~self._seen
        self._seen |= fresh
        self._front = fresh
        self.ledger.rounds += 1
        return fresh

    def round(self) -> list[list[Message]]:
        fresh = self._round_bits()
        return self._materialize(fresh)

    def _rounds_bits(self, k: int) -> np.ndarray:
        acc = np.zeros_like(self._front)
        for _ in range(k):
            if not self._front.any():
                break  # quiescent
            acc |= self._round_bits()
        return acc

    def rounds(self, k: int) -> list[list[Message]]:
        return self._materialize(self._rounds_bits(k))

    def rounds_arrays(self, k: int) \
            -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Fast path: per-client (seeds, coefs, steps) arrays of the messages
        newly accepted over k rounds — no Message objects on the hot loop,
        one unpackbits over the accumulated matrix."""
        acc = self._rounds_bits(k)
        return [(self._seeds[idx], self._coefs[idx], self._steps[idx])
                for idx in self._rows_indices(acc)]

    def full_flood(self) -> list[list[Message]]:
        return self.rounds(self.diameter + 1)

    def _materialize(self, bits: np.ndarray) -> list[list[Message]]:
        return [[self._msgs[j] for j in idx]
                for idx in self._rows_indices(bits)]

    # -- churn hooks -----------------------------------------------------------
    def _drop_frontier(self, i: int) -> None:
        self._front[i] = 0

    def _anti_entropy(self, a: int, b: int, report: SyncReport) -> None:
        seen_a = int(np.bitwise_count(self._seen[a]).sum())
        seen_b = int(np.bitwise_count(self._seen[b]).sum())
        payload = digest_bytes(seen_a) + digest_bytes(seen_b)
        moved = 0
        for dst, src in ((a, b), (b, a)):
            missed = self._seen[src] & ~self._seen[dst]
            m = int(np.bitwise_count(missed).sum())
            if m:
                self._seen[dst] |= missed
                self._front[dst] |= missed
                self._catchup[dst].extend(
                    self._msgs[j] for j in self._row_indices(missed))
            moved += m
        self.ledger.sync(payload + moved * MESSAGE_BYTES, count=moved)
        report.syncs += 1
        report.transferred += moved

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> tuple[dict, dict]:
        occ = self._occ_bytes()
        arrays: dict = {
            "msgs": self._messages_arrays(self._msgs),
            "seen": self._seen[:, :occ].copy(),
            "front": self._front[:, :occ].copy(),
        }
        for i, f in enumerate(self._catchup):
            arrays[f"catchup{i}"] = np.asarray(
                [self._uid2idx[m.uid] for m in f], np.int64)
        return arrays, {"engine": "numpy", "topo": self.topo.state_dict()}

    def load_state_dict(self, arrays: dict, meta: dict) -> None:
        self.topo.load_state_dict(meta["topo"])
        msgs = self._messages_from_arrays(arrays["msgs"])
        # re-register into fresh tables: the parallel seed/coef/step arrays
        # and uid2idx rebuild deterministically from the message list, and
        # capacity regrows geometrically just as it did live
        self._msgs = []
        self._uid2idx = {}
        self._seeds = np.zeros(self._INITIAL_BITS, np.uint32)
        self._coefs = np.zeros(self._INITIAL_BITS, np.float32)
        self._steps = np.full(self._INITIAL_BITS, STEP_PAD, np.int32)
        nbytes = self._INITIAL_BITS // 8
        self._seen = np.zeros((self.n, nbytes), np.uint8)
        self._front = np.zeros((self.n, nbytes), np.uint8)
        for m in msgs:
            self._register(m)
        occ = self._occ_bytes()
        self._seen[:, :occ] = np.asarray(arrays["seen"], np.uint8)
        self._front[:, :occ] = np.asarray(arrays["front"], np.uint8)
        self._catchup = [
            [msgs[int(k)] for k in np.asarray(arrays[f"catchup{i}"], np.int64)]
            for i in range(self.n)]
        self._adj_version = -1   # force adjacency rebuild against the topo

    # -- introspection ---------------------------------------------------------
    def in_flight(self) -> int:
        return int(np.bitwise_count(self._front).sum())

    def coverage(self, uid) -> int:
        idx = self._uid2idx.get(uid)
        if idx is None:
            return 0
        return sum(self._get_bit(self._seen, i, idx) for i in range(self.n))

    def seen_uids(self, i: int) -> set:
        return {self._msgs[j].uid for j in self._row_indices(self._seen[i])}


FLOOD_BACKENDS = {"python": FloodNetwork, "numpy": VectorFloodNetwork}


def make_network(graph, backend: str = "python"):
    """Factory over the two engines; ``backend="auto"`` picks the bitset
    engine once the network is big enough for the vectorization to pay."""
    if backend == "auto":
        n = (graph.n if isinstance(graph, DynamicTopology)
             else graph.number_of_nodes())
        backend = "numpy" if n >= AUTO_VECTOR_MIN_CLIENTS else "python"
    if backend not in FLOOD_BACKENDS:
        raise KeyError(f"unknown flood backend '{backend}' "
                       f"(have {sorted(FLOOD_BACKENDS)} or 'auto')")
    return FLOOD_BACKENDS[backend](graph)


def staleness_bound(diameter: int, k: int) -> int:
    """Paper §4.5: delayed flooding with k hops/iteration bounds message
    staleness by ⌈D/k⌉ iterations."""
    return -(-diameter // k)


def flood_bytes_per_iteration(graph: nx.Graph, n_new_messages: int) -> int:
    """Upper bound on bytes a full flood of ``n_new_messages`` costs: each
    message traverses each *directed* edge at most once."""
    return 2 * graph.number_of_edges() * n_new_messages * MESSAGE_BYTES


def gossip_sr_history_bytes(t: int, n: int, graph: nx.Graph) -> int:
    """Gossip-with-shared-randomness (paper §3.2): at iteration t each edge
    carries the O(t·n) full history of seed–scalar pairs."""
    return 2 * graph.number_of_edges() * t * n * MESSAGE_BYTES
