"""Shared-randomness primitives (paper §3.1).

Every client in a SeedFlood network owns the same counter-based RNG; a 64-bit
integer seed fully determines a perturbation.  We build everything on
``jax.random`` fold-in semantics so that

  * the same seed reproduces the same perturbation on any client, any backend;
  * seeds compose hierarchically (global seed -> step -> client -> leaf);
  * nothing is stateful: seeds are data, not objects.

Seed layout
-----------
``client_seed(base, step, client)`` is the ``s_{i,t}`` of the paper: the seed a
client attaches to its message.  ``leaf_key(seed, path)`` derives the
per-tensor stream used by ``RNG_S`` (Algorithm 1) to sample the canonical
coordinates (2D leaves) or the dense Gaussian (non-2D leaves).
"""
from __future__ import annotations

import hashlib
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def key_from_seed(seed) -> jax.Array:
    """Make a PRNG key from a (possibly traced) integer seed."""
    return jax.random.PRNGKey(seed)


def path_hash(path: str) -> int:
    """Stable 31-bit hash of a parameter path (python hash() is salted)."""
    h = hashlib.blake2s(path.encode("utf-8"), digest_size=4).digest()
    return int.from_bytes(h, "little") & 0x7FFFFFFF


def client_seed(base_seed, step, client):
    """``s_{i,t}``: the seed client ``i`` attaches to its step-``t`` message.

    Kept as a plain int32 so it is exactly what travels on the wire in the
    simulator and what the sharded step folds in.  Collision-free for
    (step, client) pairs within a run: client count < 2**16.
    """
    return (jnp.asarray(base_seed, jnp.uint32)
            + jnp.asarray(step, jnp.uint32) * jnp.uint32(65536)
            + jnp.asarray(client, jnp.uint32)).astype(jnp.uint32)


def client_seeds(base_seed: int, step: int, n: int) -> np.ndarray:
    """All n clients' ``s_{i,t}`` for one step as a numpy uint32 vector.

    Bit-identical to ``client_seed`` (uint32 wraparound matches jnp) but
    stays on the host: training loops call this every iteration, and a
    per-step ``jax.vmap(client_seed)`` would re-trace each time."""
    return (np.uint32(base_seed) + np.uint32(step) * np.uint32(65536)
            + np.arange(n, dtype=np.uint32))


def message_key(seed) -> jax.Array:
    """PRNG key for a seed that arrived in a message."""
    return jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))


def leaf_key(key: jax.Array, path: str) -> jax.Array:
    """Derive the per-tensor stream (RNG_S iterates leaves in a fixed order;
    we make the order irrelevant by folding a stable path hash instead)."""
    return jax.random.fold_in(key, path_hash(path))


def subspace_key(global_seed, step, path: str) -> jax.Array:
    """Key for (re)generating the shared subspace U_l / V_l at refresh step
    ``step`` (Algorithm 1 block (A): 'Initialize RNG with seed s_glob + t')."""
    k = jax.random.fold_in(jax.random.PRNGKey(jnp.asarray(global_seed, jnp.uint32)),
                           jnp.asarray(step, jnp.uint32))
    return leaf_key(k, path)


def coord_sample(key: jax.Array, batch_shape: Sequence[int], rank: int):
    """Sample canonical coordinates (i, j) ~ Unif[r]^2 for every layer
    instance in ``batch_shape`` (scan periods and/or experts)."""
    ki, kj = jax.random.split(key)
    i = jax.random.randint(ki, tuple(batch_shape), 0, rank, dtype=jnp.int32)
    j = jax.random.randint(kj, tuple(batch_shape), 0, rank, dtype=jnp.int32)
    return i, j


def gaussian_like(key: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    """Dense Gaussian fallback perturbation for non-2D leaves (MeZO-style)."""
    return jax.random.normal(key, shape, dtype)


def tree_paths(tree: Any) -> list[str]:
    """Canonical '/'-joined path strings for every leaf of a pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_path_str(p) for p, _ in flat]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:  # pragma: no cover - future jax path types
            parts.append(str(p))
    return "/".join(parts)


def map_with_paths(fn, tree: Any):
    """tree_map that also passes the canonical path string to ``fn``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [fn(_path_str(p), v) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
