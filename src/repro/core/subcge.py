"""SubCGE — Subspace Canonical-basis Gradient Estimation (paper §3.4).

Every 2D weight ``W ∈ R^{n×m}`` gets a globally shared pair of Gaussian
subspace matrices ``U ∈ R^{n×r}``, ``V ∈ R^{m×r}`` regenerated every ``τ``
steps from the global seed (so all clients hold identical subspaces without
communicating them).  A perturbation is one *canonical coordinate* of that
subspace,

    z = U[:, i] V[:, j]^T ,     (i, j) ~ Unif[r]^2,

and the aggregate of n received messages with coefficients {α_k} is

    ΔW = U ( Σ_k α_k E_{i_k j_k} ) V^T  =  U A V^T,

i.e. a scatter-add into the tiny ``A ∈ R^{r×r}`` followed by two thin matmuls:
O(n + r·d) instead of the O(n·d) of replaying n rank-1 axpys (MeZO-style).

Generalization to stacked / expert leaves
-----------------------------------------
Production models store layers stacked for ``lax.scan`` — a leaf looks like
``(P, n, m)`` (periods) or ``(P, E, n, m)`` (periods × experts).  Each
instance along the leading *batch dims* is its own "2D layer" in the paper's
sense: it shares the per-tensor (U, V) but samples its own coordinate, and the
coefficient tensor becomes ``A ∈ R^{*B, r, r}``.

Leaves whose trailing (non-batch) shape is not 2D fall back to the paper's
dense Gaussian perturbation (Algorithm 1's ``else`` branch).

Everything here is functional and jit-safe; the structures are plain pytrees:

* ``meta``      : dict path -> LeafMeta (static)
* ``subspace``  : dict path -> UV(U, V) for matrix leaves only
* ``coords``    : dict path -> IJ(i, j) int32 arrays of the leaf's batch shape
* ``A-tree``    : dict path -> coefficient tensor (*B, r, r)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeds as seedlib
from repro.core.messages import pad_pow2
from repro.kernels import ops as kops


class UV(NamedTuple):
    U: jax.Array  # (rows, r)
    V: jax.Array  # (cols, r)


class IJ(NamedTuple):
    i: jax.Array  # (*batch_dims,) int32
    j: jax.Array  # (*batch_dims,) int32


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    """Static description of one parameter leaf.

    ``n_batch_dims`` leading dims are layer/expert instances (scan stacking);
    the remainder is the per-instance tensor.  A leaf participates in SubCGE
    iff that remainder is 2D.
    """
    shape: tuple[int, ...]
    n_batch_dims: int = 0
    frozen: bool = False  # excluded from perturbation/update (e.g. stub frontends)

    @property
    def batch_shape(self) -> tuple[int, ...]:
        return self.shape[: self.n_batch_dims]

    @property
    def inst_shape(self) -> tuple[int, ...]:
        return self.shape[self.n_batch_dims:]

    @property
    def is_matrix(self) -> bool:
        return (not self.frozen) and len(self.inst_shape) == 2


@dataclasses.dataclass(frozen=True)
class SubCGEConfig:
    rank: int = 32
    refresh_period: int = 1000   # τ; Algorithm 1 block (A)
    eps: float = 1e-3            # perturbation scale ε
    subspace_dtype: Any = jnp.float32
    # which implementation the matrix-leaf replay runs through (DESIGN.md §7):
    # "auto" -> Pallas on TPU, the bitwise pure-jnp path elsewhere;
    # "interpret" runs the real kernels through the Pallas interpreter.
    kernel_backend: str = "auto"

    def backend(self, override: str | None = None) -> str:
        """Concrete backend for this config (override wins when given)."""
        return kops.resolve_backend(
            override if override is not None else self.kernel_backend)


# ---------------------------------------------------------------------------
# meta construction
# ---------------------------------------------------------------------------

def infer_meta(params: Any,
               n_batch_dims_fn: Callable[[str, jax.Array], int] | None = None,
               frozen_fn: Callable[[str], bool] | None = None) -> dict[str, LeafMeta]:
    """Build a LeafMeta dict from a params pytree.

    Default heuristic: no batch dims; leaves with ndim >= 2 are matrices on
    their last two dims with everything before treated as batch dims.  Model
    code should pass ``n_batch_dims_fn`` for exact control (norm scales stored
    as (P, d) are *stacked vectors*, not matrices).
    """
    meta: dict[str, LeafMeta] = {}

    def visit(path: str, leaf: jax.Array):
        nb = (n_batch_dims_fn(path, leaf) if n_batch_dims_fn is not None
              else max(0, leaf.ndim - 2))
        frz = frozen_fn(path) if frozen_fn is not None else False
        meta[path] = LeafMeta(tuple(leaf.shape), nb, frz)
        return leaf

    seedlib.map_with_paths(visit, params)
    return meta


# ---------------------------------------------------------------------------
# subspace generation (Algorithm 1, block (A))
# ---------------------------------------------------------------------------

def make_subspace(meta: dict[str, LeafMeta], cfg: SubCGEConfig,
                  global_seed, step) -> dict[str, UV]:
    """(Re)generate the shared low-rank subspace for every matrix leaf.

    Deterministic in (global_seed, refresh-step, path): any client calling
    this with the same arguments obtains bitwise-identical U/V — this is the
    "globally shared without communication" property.
    """
    out: dict[str, UV] = {}
    for path, m in sorted(meta.items()):
        if not m.is_matrix:
            continue
        rows, cols = m.inst_shape
        k = seedlib.subspace_key(global_seed, step, path)
        ku, kv = jax.random.split(k)
        U = jax.random.normal(ku, (rows, cfg.rank), cfg.subspace_dtype)
        V = jax.random.normal(kv, (cols, cfg.rank), cfg.subspace_dtype)
        out[path] = UV(U, V)
    return out


def refresh_step(step, cfg: SubCGEConfig):
    """The refresh step governing the current subspace: τ·⌊t/τ⌋."""
    tau = jnp.asarray(cfg.refresh_period, jnp.int32)
    return (jnp.asarray(step, jnp.int32) // tau) * tau


def subspace_at_step(meta, cfg: SubCGEConfig, global_seed, step):
    """Subspace in effect at iteration ``step`` (jit-safe: regenerates from
    the governing refresh step — identical on every client/shard)."""
    return make_subspace(meta, cfg, global_seed, refresh_step(step, cfg))


# ---------------------------------------------------------------------------
# coordinate sampling (RNG_S, matrix branch)
# ---------------------------------------------------------------------------

def sample_coords(meta: dict[str, LeafMeta], cfg: SubCGEConfig,
                  message_seed) -> dict[str, IJ]:
    """RNG_S: from one message seed, sample (i, j) for every matrix-leaf
    instance.  Deterministic in the seed — this is what makes the message
    reconstructible anywhere."""
    key = seedlib.message_key(message_seed)
    out: dict[str, IJ] = {}
    for path, m in sorted(meta.items()):
        if not m.is_matrix:
            continue
        i, j = seedlib.coord_sample(seedlib.leaf_key(key, path),
                                    m.batch_shape, cfg.rank)
        out[path] = IJ(i, j)
    return out


# ---------------------------------------------------------------------------
# perturbation materialization (simulator / oracle path)
# ---------------------------------------------------------------------------

def _outer_from_coords(uv: UV, ij: IJ) -> jax.Array:
    """z[*B] = U[:, i[*B]] ⊗ V[:, j[*B]]  -> (*B, rows, cols)."""
    u = jnp.moveaxis(uv.U[:, ij.i], 0, -1)      # (*B, rows)
    v = jnp.moveaxis(uv.V[:, ij.j], 0, -1)      # (*B, cols)
    return u[..., :, None] * v[..., None, :]


def materialize_z(params: Any, meta: dict[str, LeafMeta], cfg: SubCGEConfig,
                  subspace: dict[str, UV], message_seed) -> Any:
    """Full perturbation pytree z for one message (RNG_S of Algorithm 1).

    Matrix leaves: canonical-coordinate rank-1 outer products.
    Other leaves : dense Gaussian from the message seed.
    Frozen leaves: zeros.
    Only used by the simulator / tests — the sharded runtime never
    materializes z (it fuses the rank-1 term into the matmuls).
    """
    coords = sample_coords(meta, cfg, message_seed)
    key = seedlib.message_key(message_seed)

    def visit(path: str, leaf: jax.Array):
        m = meta[path]
        if m.frozen:
            return jnp.zeros_like(leaf)
        if m.is_matrix:
            return _outer_from_coords(subspace[path], coords[path]).astype(leaf.dtype)
        return seedlib.gaussian_like(seedlib.leaf_key(key, path),
                                     m.shape, leaf.dtype)

    return seedlib.map_with_paths(visit, params)


# ---------------------------------------------------------------------------
# aggregation: scatter into A, apply U A V^T  (paper eq. 10)
# ---------------------------------------------------------------------------

def scatter_A(i: jax.Array, j: jax.Array, coefs: jax.Array,
              rank: int) -> jax.Array:
    """Σ_k coef_k · E_{i_k j_k}, batched over leading instance dims.

    i, j   : (K, *B) int32 — coordinates of K messages for each instance
    coefs  : (K,) or (K, *B) — message coefficients
    returns: (*B, rank, rank)
    """
    K = i.shape[0]
    B = i.shape[1:]
    if coefs.ndim == 1:
        coefs = jnp.broadcast_to(coefs.reshape((K,) + (1,) * len(B)), (K,) + B)
    A = jnp.zeros(B + (rank, rank), coefs.dtype)
    if B:
        bidx = tuple(jnp.broadcast_to(b, (K,) + B) for b in jnp.indices(B))
    else:
        bidx = ()
    return A.at[bidx + (i, j)].add(coefs)


def apply_A(leaf: jax.Array, uv: UV, A: jax.Array,
            backend: str | None = None) -> jax.Array:
    """leaf + U A V^T (batched over instance dims), via the kernel layer.

    ``backend=None`` resolves the process default (jnp off-TPU — bitwise the
    historical einsum); callers holding a :class:`SubCGEConfig` pass
    ``cfg.kernel_backend`` so the knob is captured at trace time.
    """
    return kops.subcge_apply(leaf, uv.U, A, uv.V, backend=backend)


def delta_from_A(uv: UV, A: jax.Array, dtype,
                 backend: str | None = None) -> jax.Array:
    return kops.subcge_delta(uv.U, A, uv.V, dtype, backend=backend)


def apply_messages(params: Any, meta: dict[str, LeafMeta], cfg: SubCGEConfig,
                   subspace: dict[str, UV], message_seeds: jax.Array,
                   coefs: jax.Array) -> Any:
    """Apply K seed-scalar messages at once (Algorithm 1 block (C) inner
    update, vectorized).  ``message_seeds``: (K,) uint32; ``coefs``: (K,)
    already carrying the -η·α/n sign/scale convention of the caller.

    Matrix leaves: one scatter + one batched U A V^T per leaf — O(K + r·d),
    dispatched through the kernel layer per ``cfg.kernel_backend``.
    Vector leaves: Σ_k coef_k · N(seed_k) via a scan (memory-light).
    """
    backend = cfg.backend()
    coords_k = jax.vmap(lambda s: sample_coords(meta, cfg, s))(message_seeds)

    def visit(path: str, leaf: jax.Array):
        m = meta[path]
        if m.frozen:
            return leaf
        if m.is_matrix:
            ij = coords_k[path]
            A = scatter_A(ij.i, ij.j, coefs.astype(jnp.float32), cfg.rank)
            return apply_A(leaf, subspace[path], A, backend)

        def body(acc, sc):
            s, c = sc
            z = seedlib.gaussian_like(
                seedlib.leaf_key(seedlib.message_key(s), path),
                m.shape, jnp.float32)
            return acc + c * z, None

        upd, _ = jax.lax.scan(body, jnp.zeros(m.shape, jnp.float32),
                              (message_seeds, coefs.astype(jnp.float32)))
        return leaf + upd.astype(leaf.dtype)

    return seedlib.map_with_paths(visit, params)


# ---------------------------------------------------------------------------
# epoch-correct replay: apply each message under ITS SENDER's subspace
# ---------------------------------------------------------------------------
#
# The seed-scalar reconstruction guarantee (paper §3.1) only holds if the
# receiver regenerates the perturbation the *sender* used.  The canonical
# coordinates (i, j) depend solely on the message seed, but the subspace
# (U, V) is a function of the sender's τ-epoch ⌊t_send/τ⌋ — so a message
# whose staleness crosses a refresh boundary (delayed flooding with k < D,
# anti-entropy catch-up after an outage) MUST be applied under the epoch of
# its sender step, not the receiver's current step.  ``apply_messages_epoch``
# makes this structural: payloads carry sender steps, and the batch is
# partitioned over the epochs actually present.

#: Sentinel for unused epoch slots (matches no real refresh step, which are
#: all >= 0; slot coefficients mask to zero so the slot is an exact no-op).
EPOCH_PAD = -1


def epoch_slots(steps, cfg: SubCGEConfig, minimum: int = 1) -> np.ndarray:
    """Host-side: the distinct subspace refresh steps governing a batch of
    sender steps, padded with :data:`EPOCH_PAD` to a power-of-two length so
    jit retraces of the epoch loop stay bounded.

    ``steps`` may be any int array (e.g. the (n, K) padded matrix); negative
    entries — payload padding — are ignored.
    """
    steps = np.asarray(steps)
    tau = int(cfg.refresh_period)
    valid = steps[steps >= 0]
    uniq = np.unique((valid // tau) * tau).astype(np.int32)
    out = np.full(pad_pow2(uniq.size, minimum), EPOCH_PAD, np.int32)
    out[:uniq.size] = uniq
    return out


def apply_messages_epoch(params: Any, meta: dict[str, LeafMeta],
                         cfg: SubCGEConfig, global_seed,
                         message_seeds: jax.Array, coefs: jax.Array,
                         steps: jax.Array, epochs: jax.Array) -> Any:
    """Apply K seed-scalar messages, each under the subspace of its SENDER's
    τ-epoch (jit-safe; vmaps over a leading client axis).

    message_seeds : (K,) uint32
    coefs         : (K,)  — 0 entries are exact no-ops (payload padding)
    steps         : (K,) int32 sender steps (negative = padding)
    epochs        : (E,) int32 refresh-step slots from :func:`epoch_slots`;
                    every non-padding message's epoch must appear here

    Matrix leaves get one scatter per epoch slot; on the jnp backend the
    U_e A_e V_e^T applications run sequentially (bitwise the historical
    path — with the common single-epoch batch this is exactly
    :func:`apply_messages`), while the kernel backends fold all E slots into
    one rank-(E·r) fused visit of each weight
    (:func:`repro.kernels.ops.subcge_apply_epochs` — W streamed once, not E
    times).  Dense Gaussian (non-2D) leaves depend only on the message seed,
    never the subspace, so they are applied once, epoch-free.
    """
    backend = cfg.backend()
    coords_k = jax.vmap(lambda s: sample_coords(meta, cfg, s))(message_seeds)
    cf32 = coefs.astype(jnp.float32)
    msg_epoch = refresh_step(steps, cfg)              # (K,) — floor for < 0
    n_slots = int(epochs.shape[0])                    # static
    slot_coefs = [jnp.where(msg_epoch == epochs[e], cf32, 0.0)
                  for e in range(n_slots)]
    slot_subs = [make_subspace(meta, cfg, global_seed, epochs[e])
                 for e in range(n_slots)]

    def visit(path: str, leaf: jax.Array):
        m = meta[path]
        if m.frozen:
            return leaf
        if m.is_matrix:
            ij = coords_k[path]
            if backend == "jnp":
                out = leaf
                for sub, c_e in zip(slot_subs, slot_coefs):
                    A = scatter_A(ij.i, ij.j, c_e, cfg.rank)
                    out = apply_A(out, sub[path], A, backend)
                return out
            A_e = jnp.stack([scatter_A(ij.i, ij.j, c_e, cfg.rank)
                             for c_e in slot_coefs])          # (E, *B, r, r)
            U_e = jnp.stack([sub[path].U for sub in slot_subs])
            V_e = jnp.stack([sub[path].V for sub in slot_subs])
            return kops.subcge_apply_epochs(leaf, U_e, A_e, V_e,
                                            backend=backend)

        def body(acc, sc):
            s, c = sc
            z = seedlib.gaussian_like(
                seedlib.leaf_key(seedlib.message_key(s), path),
                m.shape, jnp.float32)
            return acc + c * z, None

        upd, _ = jax.lax.scan(body, jnp.zeros(m.shape, jnp.float32),
                              (message_seeds, cf32))
        return leaf + upd.astype(leaf.dtype)

    return seedlib.map_with_paths(visit, params)


# ---------------------------------------------------------------------------
# buffer mode (paper Appendix A): accumulate A, fold lazily
# ---------------------------------------------------------------------------

def apply_vector_messages(params: Any, meta: dict[str, LeafMeta],
                          cfg: SubCGEConfig, message_seeds: jax.Array,
                          coefs: jax.Array) -> Any:
    """Apply K messages to NON-matrix leaves only (buffer mode keeps matrix
    updates in A-buffers, but the paper's App. A follows MeZO directly for
    1D tensors — those must be applied immediately)."""
    def visit(path: str, leaf: jax.Array):
        m = meta[path]
        if m.frozen or m.is_matrix:
            return leaf

        def body(acc, sc):
            s, c = sc
            z = seedlib.gaussian_like(
                seedlib.leaf_key(seedlib.message_key(s), path),
                m.shape, jnp.float32)
            return acc + c * z, None

        upd, _ = jax.lax.scan(body, jnp.zeros(m.shape, jnp.float32),
                              (message_seeds, coefs.astype(jnp.float32)))
        return leaf + upd.astype(leaf.dtype)

    return seedlib.map_with_paths(visit, params)


def zero_buffers(meta: dict[str, LeafMeta], cfg: SubCGEConfig) -> dict[str, jax.Array]:
    """A-buffers for every matrix leaf (the paper's per-layer ``A_ℓ``)."""
    return {p: jnp.zeros(m.batch_shape + (cfg.rank, cfg.rank), jnp.float32)
            for p, m in sorted(meta.items()) if m.is_matrix}


def accumulate_buffers(buffers: dict[str, jax.Array], meta, cfg: SubCGEConfig,
                       message_seeds: jax.Array, coefs: jax.Array):
    """Coordinate updates only — O(K) per leaf.  (Appendix A 'coordinate
    update' row of Table 4.)"""
    coords_k = jax.vmap(lambda s: sample_coords(meta, cfg, s))(message_seeds)
    out = dict(buffers)
    for path in buffers:
        ij = coords_k[path]
        out[path] = buffers[path] + scatter_A(ij.i, ij.j,
                                              coefs.astype(jnp.float32), cfg.rank)
    return out


def fold_buffers(params: Any, meta, subspace: dict[str, UV],
                 buffers: dict[str, jax.Array],
                 backend: str | None = None) -> Any:
    """Fold W <- W + U A V^T and conceptually reset A (caller zeroes it).
    Must be called before any subspace refresh (the buffer is only valid
    against the U/V it was accumulated under)."""
    def visit(path: str, leaf: jax.Array):
        if path in buffers:
            return apply_A(leaf, subspace[path], buffers[path], backend)
        return leaf
    return seedlib.map_with_paths(visit, params)


def effective_params(params: Any, meta, subspace, buffers,
                     backend: str | None = None) -> Any:
    """Buffer-mode effective weights W + U A V^T (computed on the fly in the
    forward pass, as the paper's GPU implementation does)."""
    return fold_buffers(params, meta, subspace, buffers, backend)


# ---------------------------------------------------------------------------
# beyond-paper: subspace momentum
# ---------------------------------------------------------------------------
#
# Classical momentum needs an O(d) velocity — exactly the optimizer state ZO
# methods exist to avoid.  But under SubCGE every update lives in the shared
# r×r coefficient space, so a velocity μ_ℓ ∈ R^{*B,r,r} per leaf (KBs, not
# GBs) gives momentum-SGD semantics at O(r²) state:
#
#     μ ← β μ + A_t,        W ← W + U μ V^T .
#
# Consensus-safe: μ is a deterministic function of the (identical) message
# stream, so all clients hold the same velocity without communication.  The
# velocity is only meaningful within one subspace window — reset (or fold)
# at τ-refresh boundaries.  Non-2D leaves keep plain SGD (their Gaussian
# updates would need O(d) state).

def momentum_apply(params: Any, meta: dict[str, LeafMeta], cfg: SubCGEConfig,
                   subspace: dict[str, UV], velocity: dict[str, jax.Array],
                   message_seeds: jax.Array, coefs: jax.Array,
                   beta: float = 0.9):
    """One momentum step from K messages; returns (params, new_velocity).

    Matrix leaves: μ ← β μ + Σ_k coef_k E_{i_k j_k};  W += U μ V^T
    (the fold dispatched through the kernel layer per ``cfg.kernel_backend``).
    Vector leaves: plain (momentum-free) application.
    """
    backend = cfg.backend()
    coords_k = jax.vmap(lambda s: sample_coords(meta, cfg, s))(message_seeds)
    new_vel: dict[str, jax.Array] = {}

    def visit(path: str, leaf: jax.Array):
        m = meta[path]
        if m.frozen:
            return leaf
        if m.is_matrix:
            ij = coords_k[path]
            A = scatter_A(ij.i, ij.j, coefs.astype(jnp.float32), cfg.rank)
            mu = beta * velocity[path] + A
            new_vel[path] = mu
            return apply_A(leaf, subspace[path], mu, backend)

        def body(acc, sc):
            s, c = sc
            z = seedlib.gaussian_like(
                seedlib.leaf_key(seedlib.message_key(s), path),
                m.shape, jnp.float32)
            return acc + c * z, None

        upd, _ = jax.lax.scan(body, jnp.zeros(m.shape, jnp.float32),
                              (message_seeds, coefs.astype(jnp.float32)))
        return leaf + upd.astype(leaf.dtype)

    out = seedlib.map_with_paths(visit, params)
    return out, new_vel
