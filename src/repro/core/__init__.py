"""Core SeedFlood machinery: shared-randomness seeds, SubCGE subspace
gradient estimation, ZO estimators, flooding consensus, gossip baselines."""
from repro.core import seeds, subcge, zo, flood, gossip, messages  # noqa: F401
