"""The sfcheck rule engine: source loading, suppressions, and the driver.

The engine is deliberately small and stdlib-only (``ast`` + ``re``):

* :class:`SourceFile` — one parsed file: AST, per-line ``# sfcheck: noqa``
  suppressions, and path-segment helpers rules use to scope themselves.
* :class:`Project`    — every file of one run plus the cross-module
  indexes: the class hierarchy and, since sfcheck v2, the whole-program
  dataflow pass (:mod:`repro.analysis.dataflow` — call graph, per-
  function summaries, called-under-jit / donation fixpoints) built once
  and shared by every rule; constructible from in-memory sources so
  rule fixtures don't touch the filesystem.
* :func:`run_rules`   — per-file visitors + project passes, then the
  suppression filter.  A suppression without a justification comment is
  itself reported (SF000) — the tree must record *why* each invariant
  hold at each suppressed site, not merely that someone silenced it.
* renderers           — ``human`` (the default ``path:line:col: CODE``
  lines), ``github`` (workflow commands that surface as inline PR
  annotations), and ``sarif`` (SARIF 2.1.0 JSON for code-scanning
  upload / artifact archival).
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import io
import json
import re
import sys
import tokenize
from pathlib import Path, PurePosixPath
from typing import TYPE_CHECKING, Iterable, Sequence

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.analysis.dataflow import ProjectDataflow
#: Engine-level code for malformed / unjustified suppression comments.
SUPPRESSION_CODE = "SF000"
#: Engine-level code for files that do not parse at all.
PARSE_ERROR_CODE = "SF900"

_NOQA_RE = re.compile(
    r"#\s*sfcheck:\s*noqa"            # the marker
    r"(?:\[(?P<codes>[A-Z0-9,\s]*)\])?"  # optional [SF001,SF003]
    r"(?P<rest>.*)$")                 # justification tail


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: ``path:line:col: CODE message``."""
    code: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    codes: frozenset[str] | None      # None = blanket (all codes)
    justification: str


class SourceFile:
    """One file under analysis: text, AST, and suppression table."""

    def __init__(self, rel: str, text: str):
        self.rel = PurePosixPath(rel).as_posix()
        self.parts = PurePosixPath(self.rel).parts
        self.text = text
        self.lines = text.splitlines()
        self.parse_error: SyntaxError | None = None
        try:
            self.tree: ast.Module | None = ast.parse(text)
        except SyntaxError as e:
            self.tree = None
            self.parse_error = e
        self.suppressions: dict[int, Suppression] = {}
        # real COMMENT tokens only — "# sfcheck: noqa" inside a string
        # literal (e.g. this checker's own fixtures) is not a suppression
        for lineno, comment in self._comments(text):
            m = _NOQA_RE.search(comment)
            if m is None:
                continue
            codes = None
            if m.group("codes") is not None:
                codes = frozenset(
                    c.strip() for c in m.group("codes").split(",") if c.strip())
            just = m.group("rest").strip().lstrip("-—").strip()
            self.suppressions[lineno] = Suppression(lineno, codes, just)

    @staticmethod
    def _comments(text: str) -> list[tuple[int, str]]:
        try:
            return [(tok.start[0], tok.string)
                    for tok in tokenize.generate_tokens(
                        io.StringIO(text).readline)
                    if tok.type == tokenize.COMMENT]
        except (tokenize.TokenError, SyntaxError):
            # unparsable file: SF900 is reported anyway; best-effort scan
            return [(i, line) for i, line in
                    enumerate(text.splitlines(), start=1) if "#" in line]

    @classmethod
    def from_path(cls, path: Path, root: Path) -> "SourceFile":
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        return cls(rel, path.read_text(encoding="utf-8"))

    # -- path predicates rules scope themselves with --------------------------

    def in_dir(self, name: str) -> bool:
        """True when a path segment equals ``name`` (e.g. "launch")."""
        return name in self.parts[:-1]

    @property
    def top(self) -> str:
        """First path segment: "src", "tests", "benchmarks", "examples"."""
        return self.parts[0] if len(self.parts) > 1 else ""

    def is_suppressed(self, diag: Diagnostic) -> bool:
        sup = self.suppressions.get(diag.line)
        if sup is None:
            return False
        return sup.codes is None or diag.code in sup.codes


class Project:
    """All files of one run + lazily built cross-module indexes."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)
        self._class_index: dict[str, list[tuple[SourceFile, ast.ClassDef]]] | None = None
        self._dataflow: "ProjectDataflow | None" = None

    @classmethod
    def from_sources(cls, sources: dict[str, str]) -> "Project":
        """In-memory construction (rule fixtures): {rel_path: source_text}."""
        return cls([SourceFile(rel, text) for rel, text in sources.items()])

    def parsed(self) -> Iterable[SourceFile]:
        return (f for f in self.files if f.tree is not None)

    def dataflow(self) -> "ProjectDataflow":
        """The whole-program pass (call graph, summaries, fixpoints),
        built on first use and shared by every rule of the run."""
        if self._dataflow is None:
            from repro.analysis.dataflow import ProjectDataflow
            self._dataflow = ProjectDataflow(self)
        return self._dataflow

    # -- class hierarchy (the lightweight cross-module pass) -------------------

    def class_index(self) -> dict[str, list[tuple[SourceFile, ast.ClassDef]]]:
        if self._class_index is None:
            idx: dict[str, list[tuple[SourceFile, ast.ClassDef]]] = {}
            for f in self.parsed():
                for node in ast.walk(f.tree):
                    if isinstance(node, ast.ClassDef):
                        idx.setdefault(node.name, []).append((f, node))
            self._class_index = idx
        return self._class_index

    def subclasses_of(self, base: str) -> set[str]:
        """Names of ``base`` and all its transitive subclasses, resolved by
        class *name* across modules (bases written as ``mod.Cls`` match on
        the final attribute) — deliberately approximate but cheap."""
        idx = self.class_index()
        children: dict[str, set[str]] = {}
        for name, defs in idx.items():
            for _, node in defs:
                for b in node.bases:
                    bname = None
                    if isinstance(b, ast.Name):
                        bname = b.id
                    elif isinstance(b, ast.Attribute):
                        bname = b.attr
                    if bname is not None:
                        children.setdefault(bname, set()).add(name)
        out, frontier = {base}, [base]
        while frontier:
            for sub in children.get(frontier.pop(), ()):
                if sub not in out:
                    out.add(sub)
                    frontier.append(sub)
        return out


def _check_suppressions(project: Project,
                        active_codes: set[str]) -> list[Diagnostic]:
    """SF000: every suppression must name known codes and carry a reason."""
    out = []
    for f in project.files:
        for sup in f.suppressions.values():
            if sup.codes is not None:
                unknown = [c for c in sup.codes if c not in active_codes]
                if unknown:
                    out.append(Diagnostic(
                        SUPPRESSION_CODE, f.rel, sup.line, 1,
                        f"suppression names unknown rule(s) "
                        f"{sorted(unknown)}"))
            if not sup.justification:
                out.append(Diagnostic(
                    SUPPRESSION_CODE, f.rel, sup.line, 1,
                    "suppression without a justification — say why the "
                    "invariant holds here: # sfcheck: noqa[SFxxx] -- <why>"))
    return out


def run_rules(project: Project, rules=None,
              select: set[str] | None = None) -> list[Diagnostic]:
    """Run every rule over ``project``; returns unsuppressed diagnostics,
    sorted by (path, line, code)."""
    if rules is None:
        from repro.analysis.rules import RULES
        rules = RULES
    if select:
        rules = [r for r in rules if r.code in select]
    diags: list[Diagnostic] = []
    for f in project.files:
        if f.parse_error is not None:
            diags.append(Diagnostic(
                PARSE_ERROR_CODE, f.rel, f.parse_error.lineno or 1,
                f.parse_error.offset or 1,
                f"syntax error: {f.parse_error.msg}"))
    for rule in rules:
        diags.extend(rule.check_project(project))
        for f in project.parsed():
            diags.extend(rule.check_file(f, project))
    by_rel = {f.rel: f for f in project.files}
    diags = [d for d in diags
             if d.path not in by_rel or not by_rel[d.path].is_suppressed(d)]
    all_codes = {r.code for r in rules} | {SUPPRESSION_CODE, PARSE_ERROR_CODE}
    diags.extend(_check_suppressions(project, all_codes))
    return sorted(diags, key=lambda d: (d.path, d.line, d.col, d.code))


# ---------------------------------------------------------------------------
# output renderers
# ---------------------------------------------------------------------------

def _gh_escape(s: str) -> str:
    """GitHub workflow-command escaping for message data."""
    return s.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")


def render_github(diags: Sequence[Diagnostic]) -> list[str]:
    """``::error`` workflow commands — GitHub renders them as inline PR
    annotations when printed from a step."""
    return [f"::error file={d.path},line={d.line},col={d.col},"
            f"title=sfcheck {d.code}::{_gh_escape(d.message)}"
            for d in diags]


def _rule_catalogue(rules=None) -> list[tuple[str, str, str]]:
    if rules is None:
        from repro.analysis.rules import RULES
        rules = RULES
    cat = [(r.code, r.name, r.summary) for r in rules]
    cat.append((SUPPRESSION_CODE, "suppression-hygiene",
                "noqa comments must name known rules and carry a "
                "justification"))
    cat.append((PARSE_ERROR_CODE, "parse-error",
                "file does not parse"))
    return cat


def sarif_report(diags: Sequence[Diagnostic], rules=None) -> dict:
    """Minimal SARIF 2.1.0 log: one run, one result per diagnostic, the
    full rule catalogue in tool.driver.rules (so code-scanning viewers
    can show rule help even for clean runs)."""
    return {
        "$schema": ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                    "master/Schemata/sarif-schema-2.1.0.json"),
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": "sfcheck",
                "informationUri": "DESIGN.md",
                "rules": [{"id": code,
                           "name": name,
                           "shortDescription": {"text": summary}}
                          for code, name, summary in _rule_catalogue(rules)],
            }},
            "results": [{
                "ruleId": d.code,
                "level": "error",
                "message": {"text": d.message},
                "locations": [{"physicalLocation": {
                    "artifactLocation": {"uri": d.path,
                                         "uriBaseId": "%SRCROOT%"},
                    "region": {"startLine": d.line, "startColumn": d.col},
                }}],
            } for d in diags],
        }],
    }


def render(diags: Sequence[Diagnostic], fmt: str) -> str:
    if fmt == "github":
        return "\n".join(render_github(diags))
    if fmt == "sarif":
        return json.dumps(sarif_report(diags), indent=2, sort_keys=True)
    return "\n".join(d.render() for d in diags)


# ---------------------------------------------------------------------------
# filesystem driver / CLI
# ---------------------------------------------------------------------------

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


def discover(paths: Sequence[str | Path], root: Path) -> list[SourceFile]:
    files: list[SourceFile] = []
    seen: set[Path] = set()
    for p in paths:
        p = Path(p)
        if p.is_dir():
            cands = sorted(q for q in p.rglob("*.py")
                           if not any(part in _SKIP_DIRS or
                                      part.startswith(".")
                                      for part in q.parts))
        else:
            cands = [p]
        for q in cands:
            rq = q.resolve()
            if rq not in seen:
                seen.add(rq)
                files.append(SourceFile.from_path(q, root))
    return files


def check_paths(paths: Sequence[str | Path], root: str | Path | None = None,
                select: set[str] | None = None) -> list[Diagnostic]:
    root = Path(root) if root is not None else Path.cwd()
    project = Project(discover(paths, root))
    return run_rules(project, select=select)


def main(argv: Sequence[str] | None = None) -> int:
    from repro.analysis.rules import RULES
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="sfcheck: AST invariant checker for the SeedFlood tree")
    parser.add_argument("paths", nargs="*",
                        default=["src", "tests", "benchmarks", "examples"],
                        help="files/directories to check (default: the tree)")
    parser.add_argument("--select", default="",
                        help="comma-separated rule codes to run (default all)")
    parser.add_argument("--format", dest="fmt", default="human",
                        choices=("human", "github", "sarif"),
                        help="output format: human lines (default), GitHub "
                             "::error annotations, or SARIF 2.1.0 JSON")
    parser.add_argument("--output", default="",
                        help="write the report to this file instead of stdout")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for r in RULES:
            print(f"{r.code}  {r.name}: {r.summary}")
        print(f"{SUPPRESSION_CODE}  suppression-hygiene: noqa comments must "
              "name known rules and carry a justification")
        return 0

    select = ({c.strip() for c in args.select.split(",") if c.strip()}
              or None)
    paths = [p for p in args.paths if Path(p).exists()]
    project = Project(discover(paths, Path.cwd()))
    diags = run_rules(project, select=select)
    report = render(diags, args.fmt)
    if args.output:
        Path(args.output).write_text(report + "\n", encoding="utf-8")
    elif report or args.fmt == "sarif":
        print(report)
    if diags:
        print(f"\nsfcheck: {len(diags)} finding(s) in "
              f"{len(project.files)} file(s)", file=sys.stderr)
        return 1
    print(f"sfcheck: {len(project.files)} file(s) clean", file=sys.stderr)
    return 0
