"""Whole-program dataflow facts for sfcheck (DESIGN.md §8).

PR 7's rules were per-file AST visitors; every one of the repo's worst
historical bugs (receiver-epoch replay, per-trace backend sniffing, the
per-token jit-in-loop recompile) was *interprocedural* — visible only by
following a value or a call across function and module boundaries.  This
module is the project-level half of the engine:

* :class:`FileSummary`  — everything a rule repeatedly recomputed per
  file (import map, parent map, rebound globals, attribute loads,
  identifier string constants), computed once and cached.
* :class:`FunctionInfo` — one function with a module-qualified name
  (``repro.core.subcge.apply_A``, ``repro.serve.server.DecodeServer.step``,
  ``repro.dtrain.methods.seedflood.SeedFloodMethod.init.replay_batched``),
  its params, jit decoration / donation spec, and its call sites.
* :class:`ProjectDataflow` — the cross-module indexes: a call graph with
  *confident* edges only (lexical scope, module-level defs, import
  following within the project, ``self.method(...)`` with base-class
  walk, ``self._x = fn`` attribute aliases), plus two summary fixpoints:

  - ``traced``  — transitive **called-under-jit**: jit/pmap-decorated or
    jit-wrapped functions, everything they (transitively) call, and
    their lexically nested defs.  SF002 checks host-state reads against
    this set instead of per-file decorator scans.
  - donation   — **donates-through**: a function that passes its own
    parameter at a donated position of a donating callee invalidates
    that argument for *its* callers too (SF008).

* :class:`LocalFlows` — per-function **value-flows-from** facts: for a
  name or expression, the set of origins (parameters, attribute reads,
  constants) it may derive from, with scalar-substitution constructors
  (``np.where``/``np.full``/ternaries) tagged so SF010 can spot a
  receiver step being broadcast over a payload's sender steps.

Resolution is deliberately approximate but *sound in the direction each
rule needs*: unresolvable calls simply contribute no edge (rules stay
quiet) rather than guessing.  Everything is stdlib-only ``ast``.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import TYPE_CHECKING, Iterable

from repro.analysis.rules.common import (canonical, dotted, import_map,
                                         parent_map)

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.analysis.engine import Project, SourceFile

#: Callables whose first argument becomes a traced program.
JIT_WRAPPERS = ("jax.jit", "jax.pmap")
_PARTIALS = ("functools.partial", "partial")


def module_name(parts: tuple[str, ...]) -> str:
    """Dotted module name for a repo-relative path: ``src/repro/core/flood.py``
    -> ``repro.core.flood`` (the importable name), ``tests/test_x.py`` ->
    ``tests.test_x`` (a stable pseudo-module for non-package files)."""
    segs = list(parts)
    if segs and segs[0] == "src":
        segs = segs[1:]
    if segs and segs[-1].endswith(".py"):
        segs[-1] = segs[-1][: -len(".py")]
    if segs and segs[-1] == "__init__":
        segs = segs[:-1]
    return ".".join(segs)


def rebound_globals(tree: ast.Module) -> set[str]:
    """Module-level names that are *mutable state*: assigned more than
    once at module scope, or assigned anywhere under a ``global``
    declaration.  Single-assignment module constants don't count."""
    counts: dict[str, int] = {}
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = [t for t in stmt.targets if isinstance(t, ast.Name)]
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)) \
                and isinstance(stmt.target, ast.Name):
            targets = [stmt.target]
        for t in targets:
            counts[t.id] = counts.get(t.id, 0) + 1
    rebound = {n for n, c in counts.items() if c > 1}
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            rebound.update(n for n in node.names if n in counts)
    return rebound


def _canonical_of(node: ast.AST, imports: dict[str, str]) -> str | None:
    c = dotted(node)
    if c is None:
        return None
    head, _, rest = c.partition(".")
    base = imports.get(head, head)
    return f"{base}.{rest}" if rest else base


def is_jit_call(call: ast.Call, imports: dict[str, str]) -> bool:
    """True when ``call`` is ``jax.jit(...)`` / ``jax.pmap(...)``."""
    return _canonical_of(call.func, imports) in JIT_WRAPPERS


def jit_decoration(dec: ast.AST, imports: dict[str, str],
                   params: list[str]) -> tuple[int, ...] | None:
    """``None`` when the decorator does not jit the function; otherwise the
    tuple of donated positional indices (usually empty).  Handles bare
    ``@jax.jit``, ``@jax.jit(...)`` and ``@functools.partial(jax.jit, ...)``.
    """
    c = _canonical_of(dec, imports)
    if c in JIT_WRAPPERS or c == "jit":
        return ()
    if isinstance(dec, ast.Call):
        c = _canonical_of(dec.func, imports)
        if c in JIT_WRAPPERS:
            return donate_positions(dec.keywords, params)
        if c in _PARTIALS and dec.args:
            inner = _canonical_of(dec.args[0], imports)
            if inner in JIT_WRAPPERS or inner == "jit":
                return donate_positions(dec.keywords, params)
    return None


def donate_positions(keywords: Iterable[ast.keyword],
                     params: list[str]) -> tuple[int, ...]:
    """Donated positional indices from ``donate_argnums=``/``donate_argnames=``
    keyword literals (non-literal specs are ignored: no edge, no finding)."""
    out: list[int] = []
    for kw in keywords:
        if kw.arg == "donate_argnums":
            vals = (kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    out.append(v.value)
        elif kw.arg == "donate_argnames":
            vals = (kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            for v in vals:
                if isinstance(v, ast.Constant) and v.value in params:
                    out.append(params.index(v.value))
    return tuple(sorted(set(out)))


def scope_nodes(fn: ast.AST, *, into_lambdas: bool = True) -> Iterable[ast.AST]:
    """Nodes of one function's executable scope: descends into lambdas and
    comprehensions (they run when the function runs) but not into nested
    ``def``/``class`` bodies (separate scopes with their own summaries)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(node, ast.Lambda) and not into_lambdas:
            continue
        stack.extend(ast.iter_child_nodes(node))


def param_names(args: ast.arguments) -> list[str]:
    out = [a.arg for a in args.posonlyargs + args.args]
    out.extend(a.arg for a in args.kwonlyargs)
    if args.vararg:
        out.append(args.vararg.arg)
    if args.kwarg:
        out.append(args.kwarg.arg)
    return out


@dataclasses.dataclass
class FunctionInfo:
    """Summary of one function definition (module-qualified)."""

    qname: str
    name: str
    fsum: "FileSummary"
    node: ast.FunctionDef | ast.AsyncFunctionDef
    cls: ast.ClassDef | None
    parent: "FunctionInfo | None"
    params: list[str]
    jit_decorated: bool = False
    deco_donated: tuple[int, ...] = ()
    wrap_donated: tuple[int, ...] = ()        # via g = jax.jit(f, donate_...)
    through_donated: tuple[int, ...] = ()     # fixpoint: passes own param on
    nested: dict[str, "FunctionInfo"] = dataclasses.field(default_factory=dict)
    #: local ``name = jax.jit(fn)`` aliases, resolved to the wrapped fn
    aliases: dict[str, "FunctionInfo"] = dataclasses.field(default_factory=dict)
    calls: list[ast.Call] = dataclasses.field(default_factory=list)
    refs: list[ast.Name] = dataclasses.field(default_factory=list)
    edges: list[tuple[ast.Call, "FunctionInfo"]] = \
        dataclasses.field(default_factory=list)
    ref_edges: list["FunctionInfo"] = dataclasses.field(default_factory=list)

    def donated(self) -> tuple[int, ...]:
        """All donated positional indices of this function's own params."""
        merged = set(self.deco_donated) | set(self.wrap_donated) \
            | set(self.through_donated)
        return tuple(sorted(merged))


class FileSummary:
    """Per-file facts every rule used to recompute, built exactly once."""

    def __init__(self, file: "SourceFile"):
        self.file = file
        self.module = module_name(file.parts)
        self.imports = import_map(file.tree)
        self.parents = parent_map(file.tree)
        self.rebound_globals = rebound_globals(file.tree)
        self.attr_loads: set[str] = set()
        self.str_consts: set[str] = set()
        for node in ast.walk(file.tree):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, ast.Load):
                self.attr_loads.add(node.attr)
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, str) \
                    and node.value.isidentifier():
                self.str_consts.add(node.value)
        self.functions: list[FunctionInfo] = []
        self.module_funcs: dict[str, FunctionInfo] = {}
        #: jit-wrap call records: (enclosing FunctionInfo | None, call node)
        self.jit_wraps: list[tuple[FunctionInfo | None, ast.Call]] = []
        #: ``name = jax.jit(fn)`` records: (scope fi | None, name, call node)
        self.jit_wrap_aliases: list[tuple[FunctionInfo | None, str,
                                          ast.Call]] = []
        #: module-scope jit-wrap aliases resolved to the wrapped function
        self.module_alias_funcs: dict[str, FunctionInfo] = {}
        #: raw ``self.X = <Name>`` records: (method info, attr, value name)
        self.self_assigns: list[tuple[FunctionInfo, str, ast.AST]] = []
        self._build()

    # -- construction ----------------------------------------------------------

    def _build(self) -> None:
        self._visit_body(self.file.tree.body, cls=None, parent=None,
                         prefix=self.module)
        # module-scope jit wrap calls (g = jax.jit(f) at import time)
        for node in scope_nodes(self.file.tree):
            if isinstance(node, ast.Call) and is_jit_call(node, self.imports):
                self.jit_wraps.append((None, node))
            elif self._is_wrap_alias(node):
                self.jit_wrap_aliases.append(
                    (None, node.targets[0].id, node.value))

    def _visit_body(self, body, cls, parent, prefix) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._visit_function(stmt, cls, parent, prefix)
            elif isinstance(stmt, ast.ClassDef):
                self._visit_body(stmt.body, cls=stmt, parent=None,
                                 prefix=f"{prefix}.{stmt.name}")
            elif isinstance(stmt, (ast.If, ast.Try, ast.With, ast.For,
                                   ast.While)):
                for sub in ast.iter_child_nodes(stmt):
                    if isinstance(sub, ast.stmt):
                        self._visit_body([sub], cls, parent, prefix)

    def _visit_function(self, node, cls, parent, prefix) -> None:
        params = param_names(node.args)
        fi = FunctionInfo(qname=f"{prefix}.{node.name}", name=node.name,
                          fsum=self, node=node, cls=cls, parent=parent,
                          params=params)
        for dec in node.decorator_list:
            spec = jit_decoration(dec, self.imports, params)
            if spec is not None:
                fi.jit_decorated = True
                fi.deco_donated = tuple(sorted(set(fi.deco_donated)
                                               | set(spec)))
        for sub in scope_nodes(node):
            if isinstance(sub, ast.Call):
                fi.calls.append(sub)
                if is_jit_call(sub, self.imports):
                    self.jit_wraps.append((fi, sub))
                for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
                    if isinstance(arg, ast.Name):
                        fi.refs.append(arg)
            elif self._is_wrap_alias(sub):
                self.jit_wrap_aliases.append(
                    (fi, sub.targets[0].id, sub.value))
            elif isinstance(sub, ast.Assign) and cls is not None \
                    and len(sub.targets) == 1 \
                    and isinstance(sub.targets[0], ast.Attribute) \
                    and isinstance(sub.targets[0].value, ast.Name) \
                    and sub.targets[0].value.id == "self":
                self.self_assigns.append((fi, sub.targets[0].attr, sub.value))
        self.functions.append(fi)
        if parent is not None:
            parent.nested[node.name] = fi
        elif cls is None:
            self.module_funcs[node.name] = fi
        # nested defs (their scope_nodes walk skipped them above)
        for stmt in ast.walk(node):
            if stmt is node:
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and self._directly_nested_in(stmt, node):
                self._visit_function(stmt, cls=None, parent=fi,
                                     prefix=fi.qname)

    def _is_wrap_alias(self, node) -> bool:
        """``name = jax.jit(fn, ...)`` with ``fn`` a bare name."""
        return (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)
                and is_jit_call(node.value, self.imports)
                and bool(node.value.args)
                and isinstance(node.value.args[0], ast.Name))

    def _directly_nested_in(self, stmt, fn) -> bool:
        """True when ``stmt``'s nearest enclosing def is exactly ``fn``."""
        cur = self.parents.get(stmt)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur is fn
            cur = self.parents.get(cur)
        return False

    def enclosing_function(self, node: ast.AST) -> ast.AST | None:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None


class ProjectDataflow:
    """Cross-module name resolution, call graph, and summary fixpoints."""

    def __init__(self, project: "Project"):
        self.project = project
        self.summaries: dict[str, FileSummary] = {}
        for f in project.parsed():
            self.summaries[f.rel] = FileSummary(f)
        self.index: dict[str, FunctionInfo] = {}
        self._by_node: dict[int, FunctionInfo] = {}
        for fsum in self.summaries.values():
            for fi in fsum.functions:
                self.index[fi.qname] = fi
                self._by_node[id(fi.node)] = fi
        self.attr_aliases: dict[tuple[str, str], FunctionInfo] = {}
        self._link_attr_aliases()
        self.traced_roots: set[str] = set()
        self._link_wrap_aliases()
        self._link_jit_wraps()
        self._resolve_edges()
        self.traced: set[str] = self._traced_fixpoint()
        self._donation_fixpoint()
        self._flows: dict[str, LocalFlows] = {}

    # -- public API ------------------------------------------------------------

    def summary(self, file: "SourceFile") -> FileSummary:
        return self.summaries[file.rel]

    def file_summaries(self) -> list[FileSummary]:
        return [self.summaries[f.rel] for f in self.project.parsed()]

    def functions(self) -> list[FunctionInfo]:
        return [fi for fsum in self.file_summaries() for fi in fsum.functions]

    def info_of(self, node: ast.AST) -> FunctionInfo | None:
        return self._by_node.get(id(node))

    def flows(self, fi: FunctionInfo) -> "LocalFlows":
        lf = self._flows.get(fi.qname)
        if lf is None:
            lf = LocalFlows(fi)
            self._flows[fi.qname] = lf
        return lf

    def is_traced(self, fi: FunctionInfo) -> bool:
        return fi.qname in self.traced

    # -- name resolution -------------------------------------------------------

    def resolve_name(self, name: str, fi: FunctionInfo | None,
                     fsum: FileSummary) -> FunctionInfo | None:
        """Lexical resolution of a bare name at a site inside ``fi`` (or at
        module scope of ``fsum``): nested defs of enclosing functions, then
        module-level defs, then imports followed into the project."""
        cur = fi
        while cur is not None:
            child = cur.nested.get(name) or cur.aliases.get(name)
            if child is not None:
                return child
            cur = cur.parent
        mod_fn = fsum.module_funcs.get(name) \
            or fsum.module_alias_funcs.get(name)
        if mod_fn is not None:
            return mod_fn
        target = fsum.imports.get(name)
        if target is not None:
            return self.index.get(target)
        return None

    def resolve_call(self, call: ast.Call, fi: FunctionInfo | None,
                     fsum: FileSummary) -> FunctionInfo | None:
        func = call.func
        if isinstance(func, ast.Name):
            return self.resolve_name(func.id, fi, fsum)
        if isinstance(func, ast.Attribute):
            d = dotted(func)
            if d is None:
                return None
            parts = d.split(".")
            if parts[0] == "self" and len(parts) == 2 and fi is not None \
                    and fi.cls is not None:
                return self.resolve_method(fsum, fi.cls, parts[1])
            c = canonical(func, fsum.imports)
            if c is not None:
                return self.index.get(c)
        return None

    def resolve_method(self, fsum: FileSummary, cls: ast.ClassDef,
                       meth: str, _seen: set[str] | None = None
                       ) -> FunctionInfo | None:
        """``self.meth`` resolution: own class, ``self._x = fn`` attribute
        aliases, then base classes by name across the project (the SF005
        class-hierarchy pass, walked upward)."""
        _seen = set() if _seen is None else _seen
        cls_q = f"{fsum.module}.{cls.name}"
        if cls_q in _seen:
            return None
        _seen.add(cls_q)
        hit = self.index.get(f"{cls_q}.{meth}")
        if hit is not None:
            return hit
        alias = self.attr_aliases.get((cls_q, meth))
        if alias is not None:
            return alias
        for b in cls.bases:
            bname = b.id if isinstance(b, ast.Name) else \
                (b.attr if isinstance(b, ast.Attribute) else None)
            if bname is None:
                continue
            for f2, node2 in self.project.class_index().get(bname, ()):
                fsum2 = self.summaries.get(f2.rel)
                if fsum2 is None:
                    continue
                hit = self.resolve_method(fsum2, node2, meth, _seen)
                if hit is not None:
                    return hit
        return None

    # -- construction passes ---------------------------------------------------

    def _link_attr_aliases(self) -> None:
        for fsum in self.file_summaries():
            for fi, attr, value in fsum.self_assigns:
                target = None
                if isinstance(value, ast.Name):
                    target = self.resolve_name(value.id, fi, fsum)
                elif isinstance(value, ast.Call) \
                        and is_jit_call(value, fsum.imports) \
                        and value.args and isinstance(value.args[0], ast.Name):
                    target = self.resolve_name(value.args[0].id, fi, fsum)
                    if target is not None:
                        spec = donate_positions(value.keywords, target.params)
                        target.wrap_donated = tuple(sorted(
                            set(target.wrap_donated) | set(spec)))
                if target is not None and fi.cls is not None:
                    cls_q = f"{fsum.module}.{fi.cls.name}"
                    self.attr_aliases[(cls_q, attr)] = target

    def _link_wrap_aliases(self) -> None:
        """``upd = jax.jit(f, ...)`` binds ``upd`` as a callable alias of
        ``f`` (module scope or function-local), so call sites through the
        alias resolve to the wrapped function — donations included."""
        for fsum in self.file_summaries():
            for fi, name, call in fsum.jit_wrap_aliases:
                target = self.resolve_name(call.args[0].id, fi, fsum)
                if target is None:
                    continue
                if fi is None:
                    fsum.module_alias_funcs[name] = target
                else:
                    fi.aliases[name] = target

    def _link_jit_wraps(self) -> None:
        """``jax.jit(f, ...)`` call forms: ``f`` becomes a traced root and
        collects any ``donate_argnums`` literal into its donation spec."""
        for fsum in self.file_summaries():
            for fi, call in fsum.jit_wraps:
                if not call.args or not isinstance(call.args[0], ast.Name):
                    continue
                target = self.resolve_name(call.args[0].id, fi, fsum)
                if target is None:
                    continue
                self.traced_roots.add(target.qname)
                spec = donate_positions(call.keywords, target.params)
                target.wrap_donated = tuple(sorted(
                    set(target.wrap_donated) | set(spec)))

    def _resolve_edges(self) -> None:
        for fsum in self.file_summaries():
            for fi in fsum.functions:
                for call in fi.calls:
                    target = self.resolve_call(call, fi, fsum)
                    if target is not None:
                        fi.edges.append((call, target))
                for ref in fi.refs:
                    target = self.resolve_name(ref.id, fi, fsum)
                    if target is not None:
                        fi.ref_edges.append(target)

    def _traced_fixpoint(self) -> set[str]:
        """Transitive called-under-jit: decorated/wrapped roots, everything
        they confidently call or reference, and their nested defs."""
        for fi in self.functions():
            if fi.jit_decorated:
                self.traced_roots.add(fi.qname)
        traced = set(self.traced_roots)
        frontier = [qn for qn in self.index if qn in traced]
        while frontier:
            fi = self.index[frontier.pop()]
            succs = [t for _, t in fi.edges] + fi.ref_edges \
                + list(fi.nested.values())
            for t in succs:
                if t.qname not in traced:
                    traced.add(t.qname)
                    frontier.append(t.qname)
        return traced

    def call_donations(self, call: ast.Call, fi: FunctionInfo | None,
                       fsum: FileSummary) -> list[ast.expr]:
        """Argument expressions of ``call`` that are donated to the callee
        (decorator, jit-wrap, or donate-through), shifted for bound calls."""
        callee = self.resolve_call(call, fi, fsum)
        if callee is None:
            return []
        spec = callee.donated()
        if not spec:
            return []
        shift = 1 if (isinstance(call.func, ast.Attribute)
                      and callee.params[:1] == ["self"]) else 0
        out = []
        for pos in spec:
            argi = pos - shift
            if 0 <= argi < len(call.args):
                out.append(call.args[argi])
        return out

    def _donation_fixpoint(self) -> None:
        """Donates-through: F passing its own param at a donated position of
        a donating callee donates that param for F's callers too."""
        funcs = self.functions()
        changed = True
        while changed:
            changed = False
            for fi in funcs:
                for call, _ in fi.edges:
                    for arg in self.call_donations(call, fi, fi.fsum):
                        if not isinstance(arg, ast.Name) \
                                or arg.id not in fi.params:
                            continue
                        idx = fi.params.index(arg.id)
                        if idx not in fi.through_donated:
                            fi.through_donated = tuple(sorted(
                                set(fi.through_donated) | {idx}))
                            changed = True


# ---------------------------------------------------------------------------
# local value flow (value-flows-from facts)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Origin:
    """One possible source of a value: a parameter, an attribute read, a
    constant, or an unresolved global.  ``subst`` marks origins reached
    through a scalar-substitution constructor (``np.where`` branches,
    ``np.full`` fill values, ternaries) — the shape of the PR 2 bug, where
    a receiver-local scalar was broadcast over a payload's sender steps."""

    kind: str          # "param" | "attr" | "global" | "const"
    label: str
    subst: bool = False


#: Call names that merely re-wrap their first argument's value.
_WRAPPER_CALLS = {
    "numpy.asarray", "numpy.array", "numpy.ascontiguousarray",
    "jax.numpy.asarray", "jax.numpy.array", "numpy.int32", "numpy.int64",
    "numpy.uint32", "numpy.float32", "numpy.float64", "jax.numpy.int32",
    "jax.numpy.float32", "sorted", "list", "tuple",
}
#: Attribute method calls that re-wrap the receiver's value.
_WRAPPER_METHODS = {"astype", "reshape", "copy", "ravel", "flatten",
                    "tolist", "squeeze"}
#: (canonical tail, branch arg indices) for substitution constructors.
_SUBST_CALLS = {"where": (1, 2), "select": (1,), "full": (1,),
                "full_like": (1,), "broadcast_to": (0,)}


class LocalFlows:
    """Flow-insensitive value origins for one function's scope.

    The environment maps each locally assigned name to the union of the
    origins of every expression ever assigned to it (subscript stores
    included: ``buf[:n] = steps`` adds ``steps``'s origins to ``buf``),
    iterated to a fixpoint so chains resolve.  Parameters of the function
    *and of its nested defs/lambdas* count as parameter origins — a steps
    value threaded through a vmapped lambda keeps its identity.
    """

    def __init__(self, fi: FunctionInfo):
        self.fi = fi
        self.imports = fi.fsum.imports
        self.params: set[str] = set(fi.params)
        for node in ast.walk(fi.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                self.params.update(param_names(node.args))
        self.env: dict[str, frozenset[Origin]] = {}
        assigns = self._collect_assigns(fi.node)
        for _ in range(len(assigns) + 1):
            changed = False
            for name, value in assigns:
                got = self.origins(value)
                if not got <= self.env.get(name, frozenset()):
                    self.env[name] = self.env.get(name, frozenset()) | got
                    changed = True
            if not changed:
                break

    @staticmethod
    def _collect_assigns(fn) -> list[tuple[str, ast.AST]]:
        out: list[tuple[str, ast.AST]] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    out.extend(LocalFlows._target_pairs(t, node.value))
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)) \
                    and node.value is not None:
                out.extend(LocalFlows._target_pairs(node.target, node.value))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                out.extend(LocalFlows._target_pairs(node.target, node.iter))
        return out

    @staticmethod
    def _target_pairs(target, value) -> list[tuple[str, ast.AST]]:
        if isinstance(target, ast.Name):
            return [(target.id, value)]
        if isinstance(target, ast.Subscript) \
                and isinstance(target.value, ast.Name):
            # buf[i:j] = value merges value's origins into buf
            return [(target.value.id, value)]
        if isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) \
                    and len(value.elts) == len(target.elts):
                return [p for t, v in zip(target.elts, value.elts)
                        for p in LocalFlows._target_pairs(t, v)]
            return [p for t in target.elts
                    for p in LocalFlows._target_pairs(t, value)]
        return []

    def origins(self, expr: ast.AST) -> frozenset[Origin]:
        if isinstance(expr, ast.Name):
            if expr.id in self.env:
                return self.env[expr.id]
            kind = "param" if expr.id in self.params else "global"
            return frozenset({Origin(kind, expr.id)})
        if isinstance(expr, ast.Attribute):
            return frozenset({Origin("attr", expr.attr)})
        if isinstance(expr, ast.Subscript):
            return self.origins(expr.value)
        if isinstance(expr, ast.Constant):
            return frozenset({Origin("const", repr(expr.value))})
        if isinstance(expr, ast.IfExp):
            return self._tag(self.origins(expr.body)
                             | self.origins(expr.orelse))
        if isinstance(expr, ast.Call):
            return self._call_origins(expr)
        if isinstance(expr, (ast.BinOp,)):
            return self.origins(expr.left) | self.origins(expr.right)
        if isinstance(expr, ast.UnaryOp):
            return self.origins(expr.operand)
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            out: frozenset[Origin] = frozenset()
            for e in expr.elts:
                out |= self.origins(e)
            return out
        if isinstance(expr, ast.Starred):
            return self.origins(expr.value)
        return frozenset()

    def _call_origins(self, call: ast.Call) -> frozenset[Origin]:
        c = canonical(call.func, self.imports)
        tail = c.rsplit(".", 1)[-1] if c else (
            call.func.attr if isinstance(call.func, ast.Attribute) else "")
        if tail in _SUBST_CALLS:
            out: frozenset[Origin] = frozenset()
            for i in _SUBST_CALLS[tail]:
                if i < len(call.args):
                    out |= self._tag(self.origins(call.args[i]))
            return out
        if c in _WRAPPER_CALLS and call.args:
            return self.origins(call.args[0])
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _WRAPPER_METHODS:
            return self.origins(call.func.value)
        out = frozenset()
        for arg in call.args:
            out |= self.origins(arg)
        for kw in call.keywords:
            out |= self.origins(kw.value)
        return out

    @staticmethod
    def _tag(origins: frozenset[Origin]) -> frozenset[Origin]:
        return frozenset(dataclasses.replace(o, subst=True) for o in origins)
