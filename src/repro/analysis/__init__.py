"""sfcheck — AST-based invariant checker for the SeedFlood tree.

SeedFlood's correctness rests on invariants no runtime test can enforce
exhaustively: every perturbation must be reconstructible from integer
seeds alone (seed hygiene), jit traces must not close over host state
(trace safety), float accumulation must happen in a deterministic order
(bitwise consensus/resume), every byte that crosses the network must be
charged to the CommLedger, and every kernel call must route through the
``ops`` dispatch layer.  ``sfcheck`` lint-checks those invariants at the
source level, before a trace ever runs:

    PYTHONPATH=src python -m repro.analysis src tests benchmarks examples

Rules (DESIGN.md §8 maps each to the invariant and the historical bug):

* SF001 seed hygiene           — no global RNG state, no unseeded RNGs,
                                 no wall-clock-derived seeds
* SF002 trace safety           — no host syncs / wall clock / mutable
                                 global capture inside jitted functions
* SF003 iteration order        — no iteration over sets or filesystem
                                 listings feeding order-sensitive work
* SF004 config consumption     — every config field is read somewhere
                                 (no silently-ignored knobs)
* SF005 ledger conservation    — network enqueues only happen inside
                                 Transport classes that own a CommLedger
* SF006 kernel dispatch        — no ``pallas_call`` / ``kernels.ref``
                                 call sites outside ``repro/kernels``

Suppress a finding with a justified inline comment:

    x = risky()  # sfcheck: noqa[SF003] -- why this is safe

An unjustified suppression is itself an error (SF000): the comment must
say *why* the invariant holds at that site.
"""
from repro.analysis.engine import (  # noqa: F401  (public API re-export)
    Diagnostic, Project, SourceFile, check_paths, main, run_rules,
)
from repro.analysis.rules import RULES  # noqa: F401  (public API re-export)
