"""SF006 — kernel dispatch discipline.

PR 4's contract: every hot-path op goes through ``repro.kernels.ops``,
the ONE place that resolves the ``kernel_backend`` knob, caches the
``auto`` decision, and keeps the jnp oracle bitwise-pinned.  A direct
``pl.pallas_call`` or ``kernels.ref.*`` call site anywhere else
re-opens exactly the bugs that PR fixed — per-trace backend sniffing,
divergent ``_tile`` copies, silently-unused knobs.

Outside ``src/repro/kernels/`` the rule flags:

* any ``pallas_call`` invocation or ``jax.experimental.pallas`` import;
* any import binding a ``repro.kernels`` submodule other than ``ops``
  (``ref``, ``subcge_apply``, ``rank1_matmul``, ``selective_scan``);
* attribute chains reaching ``repro.kernels.ref`` through the package.

Oracle-parity tests and benchmarks legitimately need the raw reference
kernels — they suppress at the import line with a justification.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import canonical

KERNELS_PKG = "repro.kernels"
ALLOWED_SUBMODULE = "ops"


class KernelDispatchRule(Rule):
    code = "SF006"
    name = "kernel-dispatch"
    summary = ("no pallas_call or repro.kernels.<non-ops> call sites "
               "outside src/repro/kernels — dispatch through ops.*")

    def check_file(self, file, project):
        if file.in_dir("kernels"):
            return
        imports = project.dataflow().summary(file).imports
        seen_attr: set[tuple[int, int]] = set()
        for node in ast.walk(file.tree):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "pallas_call" \
                        or isinstance(node.func, ast.Name) \
                        and node.func.id == "pallas_call":
                    yield self.diag(
                        file, node,
                        "pallas_call outside repro/kernels: raw kernel "
                        "invocations bypass backend resolution and the "
                        "jnp oracle — add the op to kernels/ops.py and "
                        "dispatch through it")
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("jax.experimental.pallas") \
                            or self._bad_kernels_module(a.name):
                        yield self.diag(
                            file, node,
                            f"import of '{a.name}' outside repro/kernels "
                            "— only kernels/ops.py may touch kernel "
                            "internals; dispatch through ops.*")
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module.startswith("jax.experimental.pallas"):
                    yield self.diag(
                        file, node,
                        f"import of '{node.module}' outside repro/kernels "
                        "— only kernels/ops.py may touch kernel internals; "
                        "dispatch through ops.*")
                elif self._bad_kernels_module(node.module):
                    yield self.diag(
                        file, node,
                        f"import from '{node.module}' outside repro/"
                        "kernels — use the ops.* dispatch layer")
                elif node.module == KERNELS_PKG:
                    for a in node.names:
                        if a.name != ALLOWED_SUBMODULE:
                            yield self.diag(
                                file, node,
                                f"import of repro.kernels.{a.name} outside "
                                "repro/kernels — only 'ops' is public; "
                                "the oracles/kernels behind it are "
                                "dispatch-layer internals")
            elif isinstance(node, ast.Attribute):
                c = canonical(node, imports)
                if c is not None and c.startswith(KERNELS_PKG + ".") \
                        and not c.startswith(
                            f"{KERNELS_PKG}.{ALLOWED_SUBMODULE}"):
                    # an alias bound straight to a bad submodule was
                    # already flagged at its import line — one finding,
                    # one justified suppression per access path
                    head = c.split(".")
                    via_alias = any(
                        self._bad_kernels_module(target) or target ==
                        f"{KERNELS_PKG}.{ALLOWED_SUBMODULE}"
                        for target in imports.values()
                        if c.startswith(target + "."))
                    pos = (node.lineno, node.col_offset)
                    if not via_alias and head[:2] == ["repro", "kernels"] \
                            and pos not in seen_attr:
                        seen_attr.add(pos)   # a.b.c walks nested Attributes
                        #                      at the same position — one diag
                        yield self.diag(
                            file, node,
                            f"reference to '{c}' outside repro/kernels — "
                            "dispatch through ops.*")

    @staticmethod
    def _bad_kernels_module(mod: str) -> bool:
        return (mod.startswith(KERNELS_PKG + ".")
                and mod != f"{KERNELS_PKG}.{ALLOWED_SUBMODULE}")
