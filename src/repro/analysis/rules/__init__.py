"""The SF0xx rule catalogue.

Each rule is a tiny object with a ``code``/``name``/``summary`` and two
hooks: ``check_file(file, project)`` for per-file AST visits and
``check_project(project)`` for the cross-module passes (config-field
consumption, the Transport class hierarchy).  DESIGN.md §8 maps each
rule to the invariant it guards and the historical bug that motivated it.
"""
from __future__ import annotations

from typing import Iterable

from repro.analysis.engine import Diagnostic, Project, SourceFile


class Rule:
    """Base: rules override one or both hooks."""

    code: str = "SF999"
    name: str = "abstract"
    summary: str = ""

    def check_file(self, file: SourceFile,
                   project: Project) -> Iterable[Diagnostic]:
        return ()

    def check_project(self, project: Project) -> Iterable[Diagnostic]:
        return ()

    def diag(self, file: SourceFile, node, message: str) -> Diagnostic:
        return Diagnostic(self.code, file.rel,
                          getattr(node, "lineno", 1),
                          getattr(node, "col_offset", 0) + 1, message)


from repro.analysis.rules.sf001_seed_hygiene import SeedHygieneRule        # noqa: E402
from repro.analysis.rules.sf002_trace_safety import TraceSafetyRule        # noqa: E402
from repro.analysis.rules.sf003_iteration_order import IterationOrderRule  # noqa: E402
from repro.analysis.rules.sf004_config_fields import ConfigFieldsRule      # noqa: E402
from repro.analysis.rules.sf005_ledger import LedgerConservationRule       # noqa: E402
from repro.analysis.rules.sf006_kernel_dispatch import KernelDispatchRule  # noqa: E402
from repro.analysis.rules.sf007_retrace import RetraceHazardRule           # noqa: E402
from repro.analysis.rules.sf008_donation import DonationSafetyRule         # noqa: E402
from repro.analysis.rules.sf009_cache_keys import CacheKeyRule             # noqa: E402
from repro.analysis.rules.sf010_epoch_flow import EpochFlowRule            # noqa: E402

#: The registry, in code order.  ``run_rules`` iterates exactly this.
RULES: list[Rule] = [
    SeedHygieneRule(),
    TraceSafetyRule(),
    IterationOrderRule(),
    ConfigFieldsRule(),
    LedgerConservationRule(),
    KernelDispatchRule(),
    RetraceHazardRule(),
    DonationSafetyRule(),
    CacheKeyRule(),
    EpochFlowRule(),
]
