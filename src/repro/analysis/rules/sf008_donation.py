"""SF008 — donation safety.

The stacked client-parameter buffers are donated into the jit dispatches
(``donate_argnums=(0,)`` on ``estimate_and_update`` / ``replay_batched``)
so XLA can update the multi-hundred-MB arrays in place.  Donation
*invalidates* the argument: after the call, the old buffer is dead and
reading it returns garbage (or raises, backend-depending).  The safe
idiom is an immediate rebind — ``stacked, ... = f(stacked, ...)`` — and
everything else is a latent use-after-free that only bites on backends
that actually reuse the buffer.

Interprocedural: the dataflow pass knows each function's donated
positions from its ``@functools.partial(jax.jit, donate_argnums=...)``
decorator, from ``jax.jit(f, donate_argnums=...)`` wrap sites (including
``self._f = jax.jit(f, ...)`` aliases), and from the *donates-through*
fixpoint — a function that forwards its own parameter into a donated
position donates that parameter for its callers too, so the hazard is
visible at every level of the call stack.

Flagged: any ``Name`` load of a donated variable on a statement after
the donating call, along any live straight-line path in the same scope.
Branch bodies are scanned with path-local environments; loop bodies are
scanned twice, so a donation in iteration *i* flags a read in iteration
*i+1* — which is exactly why the rebind idiom is clean: the rebind
clears the hazard before the next pass.  A path that *terminates*
(``return``/``raise``/``break``/``continue``) carries its donations out
of the scope, not into the next statement — ``if fused: return f(x)``
followed by an ``else``-path read of ``x`` is fine.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule


def _names_loaded(node) -> list[ast.Name]:
    """Name loads under ``node``, skipping nested def bodies (they run
    later, against whatever the name is bound to then); lambdas and
    comprehensions execute in place and are included."""
    out = []
    stack = [node]
    while stack:
        cur = stack.pop()
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(cur, ast.Name) and isinstance(cur.ctx, ast.Load):
            out.append(cur)
        stack.extend(ast.iter_child_nodes(cur))
    return out


def _names_bound(stmt) -> set[str]:
    out: set[str] = set()
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            for leaf in ast.walk(t):
                if isinstance(leaf, ast.Name) and isinstance(leaf.ctx,
                                                             ast.Store):
                    out.add(leaf.id)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)) \
            and isinstance(stmt.target, ast.Name):
        out.add(stmt.target.id)
    return out


class DonationSafetyRule(Rule):
    code = "SF008"
    name = "donation-safety"
    summary = ("no reads of a buffer after it was passed at a donated "
               "position (donate_argnums), across function boundaries")

    def check_project(self, project):
        df = project.dataflow()
        for fi in df.functions():
            seen: set[tuple[int, int]] = set()
            yield from self._scan_block(df, fi, fi.node.body, {}, seen)

    # -- path-local statement scan --------------------------------------------

    def _scan_block(self, df, fi, body, donated: dict[str, tuple[str, int]],
                    seen):
        """Walk one statement list.  ``donated`` maps name -> (callee
        label, donation line); mutated as donations/rebinds occur so the
        hazard state falls through to the caller's next statement.
        Returns True when the block definitely terminates (return/raise/
        break/continue) — the caller must then discard its environment
        instead of merging it into the fall-through path."""
        for stmt in body:
            for expr in self._headers(stmt):
                # reads of an already-dead buffer (donations from *previous*
                # statements only — the donating call's own argument read is
                # the donation itself, not a use-after)
                for name in _names_loaded(expr):
                    key = (name.lineno, name.col_offset)
                    if name.id in donated and key not in seen:
                        seen.add(key)
                        label, line = donated[name.id]
                        yield self.diag(
                            fi.fsum.file, name,
                            f"'{name.id}' was donated to {label} (line "
                            f"{line}) and read afterwards — donated "
                            "buffers are invalidated by XLA; rebind the "
                            "result (x, ... = f(x, ...)) or pass a copy")
                for call in ast.walk(expr):
                    if isinstance(call, ast.Call):
                        for arg, label in self._donated_args(df, fi, call):
                            if isinstance(arg, ast.Name):
                                donated[arg.id] = (label, call.lineno)
            for name in _names_bound(stmt):
                donated.pop(name, None)
            if isinstance(stmt, (ast.Return, ast.Raise, ast.Break,
                                 ast.Continue)):
                return True
            term = yield from self._scan_bodies(df, fi, stmt, donated, seen)
            if term:
                return True
        return False

    def _headers(self, stmt) -> list[ast.AST]:
        """Expressions evaluated *at* this statement (compound statements'
        bodies are scanned separately with path-local environments)."""
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            return [stmt.iter]
        if isinstance(stmt, (ast.While, ast.If)):
            return [stmt.test]
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return [item.context_expr for item in stmt.items]
        if isinstance(stmt, ast.Try):
            return []
        return [stmt]

    def _scan_bodies(self, df, fi, stmt, donated, seen):
        """Scan a compound statement's bodies; returns True when every
        live path through it terminates the enclosing block."""
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            env = dict(donated)
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                for leaf in ast.walk(stmt.target):
                    if isinstance(leaf, ast.Name):
                        env.pop(leaf.id, None)
            term = yield from self._scan_block(df, fi, stmt.body, env, seen)
            if not term:            # donation in iter i, read in iter i+1
                term = yield from self._scan_block(df, fi, stmt.body, env,
                                                   seen)
            yield from self._scan_block(df, fi, stmt.orelse, dict(env), seen)
            if not term:            # zero-iteration path keeps `donated` too
                donated.update(env)
            return False            # the loop as a whole falls through
        if isinstance(stmt, ast.If):
            terms = []
            for branch in (stmt.body, stmt.orelse):
                env = dict(donated)
                term = yield from self._scan_block(df, fi, branch, env, seen)
                terms.append(term)
                if not term:
                    donated.update(env)
            return bool(stmt.orelse) and all(terms)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            term = yield from self._scan_block(df, fi, stmt.body, donated,
                                               seen)
            return term
        if isinstance(stmt, ast.Try):
            for branch in ([stmt.body, stmt.orelse, stmt.finalbody]
                           + [h.body for h in stmt.handlers]):
                env = dict(donated)
                term = yield from self._scan_block(df, fi, branch, env, seen)
                if not term:
                    donated.update(env)
        return False

    # -- donation sites --------------------------------------------------------

    def _donated_args(self, df, fi, call):
        """(arg expression, callee label) pairs donated by this call."""
        out = []
        for arg in df.call_donations(call, fi, fi.fsum):
            label = f"'{ast.unparse(call.func)}'"
            out.append((arg, label))
        # immediately-invoked jit with donate: jax.jit(f, donate_...)(x)
        if isinstance(call.func, ast.Call):
            from repro.analysis.dataflow import donate_positions, is_jit_call
            inner = call.func
            if is_jit_call(inner, fi.fsum.imports):
                for pos in donate_positions(inner.keywords, []):
                    if pos < len(call.args):
                        out.append((call.args[pos], "an inline jit"))
        return out
