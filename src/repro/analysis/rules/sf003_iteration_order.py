"""SF003 — deterministic iteration order.

Bitwise consensus and bitwise resume (PRs 2–3) both hinge on float
summation happening in *the same order on every client and every run*:
flood frontier order determines payload order determines the order of
rank-1 axpys into the weights.  Iterating a ``set`` (or a filesystem
listing) hands that order to hash-table internals / the OS instead of
the protocol.  Python set iteration is *not* insertion-ordered, and for
str-keyed sets it changes across processes with hash randomization —
"it happened to agree in this run" is not evidence.

Flags iteration over *set-origin* expressions — set literals/
comprehensions, ``set()``/``frozenset()`` calls, set-algebra operators
(``| & - ^``) and methods (``union`` …) over them, and names assigned
from any of those in the same scope — when the set feeds a ``for`` loop,
a comprehension, or an order-sensitive consumer (``list``, ``tuple``,
``enumerate``, ``sum``, ``json.dump``, ``np.asarray``, ``.join``).
Order-insensitive consumers (``len``/``any``/``all``/``max``/``min``/
membership/more set algebra) are fine; ``sorted(...)`` is the blessed
fix and silences the rule.  Unsorted ``os.listdir``/``glob.glob``/
``Path.iterdir`` iteration is flagged for the same reason (checkpoint
discovery order must not depend on the filesystem).
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import call_canonical

_SET_METHODS = {"union", "intersection", "difference",
                "symmetric_difference", "copy"}
_SET_OPS = (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
#: Calls whose result enumerates the filesystem in OS-defined order.
_FS_LISTING = {"os.listdir", "os.scandir", "glob.glob", "glob.iglob"}
#: Order-sensitive consumers: passing an unordered iterable here bakes
#: hash-table order into data, floats, or serialized output.
_ORDER_SENSITIVE_CALLS = {"list", "tuple", "enumerate", "sum", "iter",
                          "numpy.asarray", "numpy.array", "numpy.stack",
                          "numpy.concatenate", "json.dump", "json.dumps",
                          "jax.numpy.asarray", "jax.numpy.array"}


class _Scope:
    """Set-origin name tracking for one function (or the module body)."""

    def __init__(self):
        self.set_names: set[str] = set()


class IterationOrderRule(Rule):
    code = "SF003"
    name = "iteration-order"
    summary = ("no iteration over sets or filesystem listings feeding "
               "order-sensitive work — wrap in sorted()")

    def check_file(self, file, project):
        imports = project.dataflow().summary(file).imports
        # module scope first: its set-origin names seed every function
        # scope (a function iterating a module-level set is the same bug)
        module_sets = yield from self._check_scope(file, file.tree, True,
                                                   imports, set())
        for node in ast.walk(file.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_scope(file, node, False, imports,
                                             module_sets)

    # -- scope walk -----------------------------------------------------------

    def _scope_body(self, scope_node, is_module):
        """Nodes belonging to this scope (module: skip function bodies —
        they are their own scopes; functions: include nested defs so
        closures over an outer set still resolve)."""
        if not is_module:
            yield from ast.walk(scope_node)
            return
        stack = list(ast.iter_child_nodes(scope_node))
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.extend(ast.iter_child_nodes(node))

    def _check_scope(self, file, scope_node, is_module, imports,
                     outer_sets: set[str]):
        scope = _Scope()
        scope.set_names |= outer_sets
        if not is_module:       # params shadow same-named module globals
            a = scope_node.args
            params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
            scope.set_names -= params
        nodes = list(self._scope_body(scope_node, is_module))
        # pass 1: which names are set-origin in this scope?
        changed = True
        while changed:                       # chains: a = set(); b = a | c
            changed = False
            for node in nodes:
                targets = []
                if isinstance(node, ast.Assign):
                    targets = [t for t in node.targets
                               if isinstance(t, ast.Name)]
                    value = node.value
                elif isinstance(node, ast.AnnAssign) \
                        and isinstance(node.target, ast.Name):
                    targets, value = [node.target], node.value
                    ann = ast.unparse(node.annotation).lower()
                    if ann.startswith(("set", "frozenset", "typing.set",
                                       "typing.frozenset")):
                        value = value or ast.Set(elts=[])
                        if node.target.id not in scope.set_names:
                            scope.set_names.add(node.target.id)
                            changed = True
                elif isinstance(node, ast.AugAssign) \
                        and isinstance(node.target, ast.Name):
                    continue                 # |= keeps origin, adds nothing
                else:
                    continue
                if value is not None and self._is_set_expr(value, scope,
                                                           imports):
                    for t in targets:
                        if t.id not in scope.set_names:
                            scope.set_names.add(t.id)
                            changed = True
        # pass 2: where do set-origin / fs-listing values leak order?
        for node in nodes:
            if isinstance(node, (ast.For, ast.AsyncFor)):
                yield from self._check_iter(file, node.iter, scope, imports,
                                            "for-loop")
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    kind = ("set-comprehension" if isinstance(node, ast.SetComp)
                            else "comprehension")
                    yield from self._check_iter(file, gen.iter, scope,
                                                imports, kind)
            elif isinstance(node, ast.Call):
                c = call_canonical(node, imports)
                if c in _ORDER_SENSITIVE_CALLS and node.args:
                    yield from self._check_iter(file, node.args[0], scope,
                                                imports, f"{c}()")
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "join" and node.args:
                    yield from self._check_iter(file, node.args[0], scope,
                                                imports, "str.join()")
        return scope.set_names

    def _check_iter(self, file, expr, scope, imports, context):
        if context in ("set-comprehension",):
            return  # building another set keeps the value unordered — fine
        if self._is_set_expr(expr, scope, imports):
            yield self.diag(
                file, expr,
                f"iteration over a set in {context}: set order is "
                "hash-table order, not protocol order — any float "
                "accumulation or serialization downstream becomes "
                "run-dependent; wrap in sorted(...)")
        elif self._is_fs_listing(expr, imports):
            yield self.diag(
                file, expr,
                f"unsorted filesystem listing in {context}: the OS "
                "defines this order — wrap in sorted(...)")

    # -- expression classification -------------------------------------------

    def _is_set_expr(self, expr, scope: _Scope, imports) -> bool:
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return True
        if isinstance(expr, ast.Name):
            return expr.id in scope.set_names
        if isinstance(expr, ast.Call):
            c = call_canonical(expr, imports)
            if c in ("set", "frozenset"):
                return True
            if isinstance(expr.func, ast.Attribute) \
                    and expr.func.attr in _SET_METHODS:
                return self._is_set_expr(expr.func.value, scope, imports)
            return False
        if isinstance(expr, ast.BinOp) and isinstance(expr.op, _SET_OPS):
            return (self._is_set_expr(expr.left, scope, imports)
                    or self._is_set_expr(expr.right, scope, imports))
        return False

    def _is_fs_listing(self, expr, imports) -> bool:
        if not isinstance(expr, ast.Call):
            return False
        c = call_canonical(expr, imports)
        if c in _FS_LISTING:
            return True
        return (isinstance(expr.func, ast.Attribute)
                and expr.func.attr == "iterdir")
