"""SF005 — ledger conservation.

The paper's headline metric is *bytes per edge*; PR 3 moved ALL byte
accounting into the Transport layer so that no method refactor can
drift the cost model.  The invariant: anything that enqueues onto a
flood/gossip network — injections, flood rounds, anti-entropy drains,
choco rounds, mixing — is reachable from ``core/``/``dtrain/`` code
only through a Transport method, because Transports own the
``CommLedger`` that charges for it.  A direct ``net.inject(...)`` from
a method or the trainer would move bytes nobody ever counts.

Cross-module pass: the class hierarchy identifies Transport classes
(transitive subclasses of ``TransportBase``); enqueue-primitive calls
in ``src/repro/core`` / ``src/repro/dtrain`` / ``src/repro/serve``
outside the substrate modules (``core/flood.py``, ``core/gossip.py`` —
where the primitives are *defined* and charge the ledger themselves)
must sit lexically inside a Transport class body.  The serving swarm is
in scope because its live-update bridge rides the flood: a server that
injected or drained the network directly would receive updates no
ledger ever billed.  Tests/benchmarks/examples drive networks directly
on purpose and are out of scope.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import call_canonical

#: Method names that enqueue onto (or drain from) a network substrate.
#: ``round`` is deliberately absent: ``ndarray.round()`` would swamp the
#: signal; ``rounds*`` and ``inject`` cover every real enqueue path.
ENQUEUE_METHODS = {"inject", "rounds", "rounds_arrays", "rounds_padded",
                   "full_flood", "drain_catchup", "drain_catchup_arrays"}

#: Module-level functions with the same property (gossip exchange).
ENQUEUE_FUNCTIONS = {"repro.core.gossip.choco_round", "repro.core.gossip.mix"}

#: Files allowed to touch the primitives freely: the substrate itself
#: (its engines charge their own ledger as part of the protocol).
SUBSTRATE = {("core", "flood.py"), ("core", "gossip.py")}

TRANSPORT_BASE = "TransportBase"


class LedgerConservationRule(Rule):
    code = "SF005"
    name = "ledger-conservation"
    summary = ("network enqueues in core/, dtrain/ and serve/ only inside "
               "Transport classes (the CommLedger owners)")

    def _in_scope(self, file) -> bool:
        if file.top != "src":
            return False
        if not (file.in_dir("core") or file.in_dir("dtrain")
                or file.in_dir("serve")):
            return False
        return tuple(file.parts[-2:]) not in SUBSTRATE

    def check_project(self, project):
        transports = project.subclasses_of(TRANSPORT_BASE)
        df = project.dataflow()
        for f in project.parsed():
            if not self._in_scope(f):
                continue
            fsum = df.summary(f)
            imports = fsum.imports
            parents = fsum.parents
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                label = self._enqueue_label(node, imports)
                if label is None:
                    continue
                cls = self._enclosing_class(node, parents)
                if cls is not None and cls.name in transports:
                    continue
                where = (f"class {cls.name}" if cls is not None
                         else "module scope")
                yield self.diag(
                    f, node,
                    f"network enqueue '{label}' from {where}: only "
                    "Transport subclasses (which own the CommLedger) may "
                    "enqueue onto a flood/gossip network — route this "
                    "through a Transport method so the bytes are charged")

    def _enqueue_label(self, node: ast.Call, imports) -> str | None:
        c = call_canonical(node, imports)
        if c in ENQUEUE_FUNCTIONS:
            return c
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ENQUEUE_METHODS:
            return f".{node.func.attr}()"
        return None

    @staticmethod
    def _enclosing_class(node, parents) -> ast.ClassDef | None:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = parents.get(cur)
        return None
