"""SF007 — retrace hazards.

``jax.jit`` caches the compiled program on the *callable object*.  Build
the callable fresh and the cache is gone: PR 9's serve loop constructed
``jax.jit(decode_fn)`` per decode step, recompiling a full forward pass
per token — hundreds of times slower, no error anywhere.  This rule
makes that bug class (and its cousins) a lint error:

* **jit inside a loop** — a ``jax.jit(...)`` call lexically under a
  ``for``/``while``.  Exempt when the construction genuinely depends on
  the iteration: the jitted program is stored into a subscript cache
  (``fns[key] = jax.jit(f)``), the wrapped callable is itself (re)bound
  inside the loop body, or a loop variable appears in the jit call's
  arguments (per-``K`` programs in a benchmark sweep are per-``K`` on
  purpose).
* **jit per call** — immediately-invoked ``jax.jit(f)(x)``: the program
  is compiled, used once, and dropped.
* **factory called in a loop** — a function that constructs jitted
  callables without caching them (a scope-local ``jax.jit`` call or a
  jit-decorated nested def), invoked under a loop.  The construction
  site looks innocent; the call site is where the recompile storm
  happens — this is the interprocedural face of the PR 9 bug.
* **closure over a rebindable global** — ``jax.jit`` applied to a
  lambda whose body reads a module global that is rebound elsewhere
  (the PR 4 backend-sniffing shape): the trace captures one value and
  later rebinds are silently ignored.  Named functions with the same
  problem are SF002's job; the lambda has no body for SF002 to attribute.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import walk_scope


def _loop_ancestry(node, fsum):
    """Loops lexically enclosing ``node`` up to its defining function."""
    out = []
    cur = fsum.parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            break
        if isinstance(cur, (ast.For, ast.AsyncFor, ast.While)):
            out.append(cur)
        cur = fsum.parents.get(cur)
    return out


def _loop_target_names(loops) -> set[str]:
    names: set[str] = set()
    for loop in loops:
        if isinstance(loop, (ast.For, ast.AsyncFor)):
            for sub in ast.walk(loop.target):
                if isinstance(sub, ast.Name):
                    names.add(sub.id)
    return names


def _names_in(node) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _bound_in_loops(name: str, loops) -> bool:
    """Is ``name`` (re)bound inside any of the enclosing loop bodies?
    A callable rebuilt per iteration legitimately gets a fresh jit."""
    for loop in loops:
        for sub in ast.walk(loop):
            if isinstance(sub, ast.Assign):
                for t in sub.targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name) and leaf.id == name:
                            return True
            elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and sub.name == name:
                return True
    return False


def _stored_in_subscript(call, fsum) -> bool:
    """``fns[key] = jax.jit(f)`` — or assigned to a name that is stored
    into a subscript in the same scope — is the cache idiom, not a leak."""
    parent = fsum.parents.get(call)
    if not isinstance(parent, ast.Assign):
        return isinstance(parent, ast.Subscript)
    for t in parent.targets:
        if isinstance(t, ast.Subscript):
            return True
    names = [t.id for t in parent.targets if isinstance(t, ast.Name)]
    if not names:
        return False
    scope = fsum.enclosing_function(call) or fsum.file.tree
    for sub in walk_scope(scope):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                if isinstance(t, ast.Subscript) \
                        and isinstance(sub.value, ast.Name) \
                        and sub.value.id in names:
                    return True
    return False


class RetraceHazardRule(Rule):
    code = "SF007"
    name = "retrace-hazard"
    summary = ("no jit construction inside loops or per call, no "
               "uncached jit factories invoked under a loop, no jit "
               "lambdas over rebindable globals")

    def check_project(self, project):
        df = project.dataflow()
        factories = self._factories(df)
        for fsum in df.file_summaries():
            yield from self._check_jit_sites(df, fsum)
            yield from self._check_factory_calls(df, fsum, factories)

    # -- direct jit construction sites ----------------------------------------

    def _check_jit_sites(self, df, fsum):
        file = fsum.file
        for fi, call in fsum.jit_wraps:
            # immediately-invoked: jax.jit(f)(x)
            parent = fsum.parents.get(call)
            if isinstance(parent, ast.Call) and parent.func is call:
                yield self.diag(
                    file, call,
                    "jit program compiled and invoked in one expression — "
                    "the compiled program is dropped after this call and "
                    "every execution retraces; bind the jitted callable "
                    "once and reuse it")
                continue
            loops = _loop_ancestry(call, fsum)
            if not loops:
                continue
            if _stored_in_subscript(call, fsum):
                continue
            loop_names = _loop_target_names(loops)
            if loop_names & _names_in(call):
                continue            # per-iteration program on purpose
            if call.args and isinstance(call.args[0], ast.Name) \
                    and _bound_in_loops(call.args[0].id, loops):
                continue            # wrapped callable is fresh per iteration
            yield self.diag(
                file, call,
                "jax.jit(...) inside a loop: jit caches compiled programs "
                "on the callable object, so a fresh wrapper per iteration "
                "recompiles every time (the PR 9 per-token decode bug) — "
                "hoist the jit out of the loop or store it in a keyed cache")
            # a lambda closing over a rebindable global is wrong even
            # outside a loop; check all wrap sites below
        for fi, call in fsum.jit_wraps:
            if call.args and isinstance(call.args[0], ast.Lambda):
                lam = call.args[0]
                lam_params = {a.arg for a in (lam.args.posonlyargs
                                              + lam.args.args
                                              + lam.args.kwonlyargs)}
                for sub in ast.walk(lam.body):
                    if isinstance(sub, ast.Name) \
                            and isinstance(sub.ctx, ast.Load) \
                            and sub.id in fsum.rebound_globals \
                            and sub.id not in lam_params:
                        yield self.diag(
                            file, sub,
                            f"jit-wrapped lambda reads mutable module "
                            f"global '{sub.id}' — the trace captures one "
                            "value and later rebinds are silently ignored "
                            "(the PR 4 backend-sniffing shape); resolve it "
                            "before wrapping")

    # -- factories: functions that build uncached jitted callables -------------

    def _factories(self, df) -> dict[str, str]:
        """qname -> why, for functions that construct jitted callables
        per invocation (uncached scope jit call or jit-decorated nested
        def).  Calling one of these in a loop retraces per iteration."""
        out: dict[str, str] = {}
        for fsum in df.file_summaries():
            for fi, call in fsum.jit_wraps:
                if fi is None:
                    continue        # module scope: runs once at import
                if _stored_in_subscript(call, fsum):
                    continue        # keyed cache — the sanctioned idiom
                out.setdefault(
                    fi.qname,
                    f"builds a jitted callable at line {call.lineno}")
        for fi2 in df.functions():
            if fi2.jit_decorated and fi2.parent is not None:
                out.setdefault(
                    fi2.parent.qname,
                    f"defines jit-decorated '{fi2.name}' per call")
        return out

    def _check_factory_calls(self, df, fsum, factories):
        file = fsum.file
        for fi in fsum.functions:
            for call, callee in fi.edges:
                why = factories.get(callee.qname)
                if why is None:
                    continue
                loops = _loop_ancestry(call, fsum)
                if not loops:
                    continue
                loop_names = _loop_target_names(loops)
                if loop_names & _names_in(call):
                    continue        # per-iteration programs on purpose
                yield self.diag(
                    file, call,
                    f"'{callee.name}' {why} and is invoked inside a loop "
                    "— every iteration recompiles (the interprocedural "
                    "PR 9 bug); hoist the call or cache the program by key")
