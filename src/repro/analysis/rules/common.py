"""Shared AST machinery for the SF0xx rules.

The one abstraction every rule leans on is *canonical names*: an import
map (local alias -> dotted module/object path) plus :func:`canonical`,
which rewrites an attribute chain like ``np.random.seed`` into
``numpy.random.seed`` regardless of what the file imported numpy as.
Rules then match on canonical prefixes instead of guessing aliases.
"""
from __future__ import annotations

import ast
from typing import Iterator


def import_map(tree: ast.Module) -> dict[str, str]:
    """Local name -> fully qualified dotted path, from every import
    statement in the file (function-local imports included: rules care
    about what a name *means*, not where it was bound)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                local = a.asname or a.name.split(".")[0]
                out[local] = a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def canonical(node: ast.AST, imports: dict[str, str]) -> str | None:
    """Canonicalized dotted path of a Name/Attribute chain: the leading
    segment is resolved through the import map (``np`` -> ``numpy``,
    ``kops`` -> ``repro.kernels.ops``)."""
    d = dotted(node)
    if d is None:
        return None
    head, _, rest = d.partition(".")
    base = imports.get(head, head)
    return f"{base}.{rest}" if rest else base


def call_canonical(node: ast.Call, imports: dict[str, str]) -> str | None:
    return canonical(node.func, imports)


def walk_scope(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body without descending into nested function /
    class *definitions* (their bodies are separate scopes)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def functions_in(tree: ast.AST) -> Iterator[ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def parent_map(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    return {child: node for node in ast.walk(tree)
            for child in ast.iter_child_nodes(node)}
