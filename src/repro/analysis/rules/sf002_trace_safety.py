"""SF002 — trace safety.

A function handed to ``jax.jit`` runs its Python body *once per trace*,
not once per step.  Host-state reads inside it are frozen into the
compiled program (wall-clock, mutable module globals — exactly the
per-trace backend sniffing PR 4 had to remove from the kernel layer) or
force a device→host sync that breaks async dispatch (``.item()``), or
simply never fire again (``print``).  All of these look correct on the
first step and silently diverge later.

A function counts as *traced* when it (or any enclosing function) is

* decorated with ``jax.jit`` / ``jax.pmap`` (bare, ``@jax.jit(...)`` or
  via ``functools.partial(jax.jit, ...)``), or
* passed by name as the first argument to a ``jax.jit(...)`` /
  ``jax.pmap(...)`` call anywhere in the same file.

Inside traced bodies (nested defs and lambdas included) the rule flags
``time.*`` clock calls, ``print(...)``, ``.item()``, ``global`` /
``nonlocal`` mutation, and reads of module-level *rebound* globals —
a global assigned more than once, or assigned under a ``global``
declaration, is mutable state whose value the trace captures silently.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import (call_canonical, dotted, import_map,
                                         parent_map)

_TRACERS = {"jax.jit", "jax.pmap"}
_CLOCKS = {"time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
           "time.perf_counter", "time.perf_counter_ns"}
_PARTIAL = {"functools.partial", "partial"}


def _decorator_traces(dec: ast.AST, imports) -> bool:
    """True when a decorator expression makes the function traced."""
    if dotted(dec) is not None:
        c = dotted(dec)
        head, _, rest = c.partition(".")
        c = f"{imports.get(head, head)}.{rest}" if rest else imports.get(head, head)
        return c in _TRACERS or c == "jit"
    if isinstance(dec, ast.Call):
        c = call_canonical(dec, imports)
        if c in _TRACERS:                         # @jax.jit(static_argnums=..)
            return True
        if c in _PARTIAL and dec.args:            # @partial(jax.jit, ...)
            return _decorator_traces(dec.args[0], imports)
    return False


def _jitted_names(tree: ast.Module, imports) -> set[str]:
    """Function names passed as the first argument of a jit/pmap call
    somewhere in this file (``jitted = jax.jit(fn, ...)``)."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and call_canonical(node, imports) in _TRACERS:
            if node.args and isinstance(node.args[0], ast.Name):
                out.add(node.args[0].id)
    return out


def _rebound_globals(tree: ast.Module) -> set[str]:
    """Module-level names that are *mutable state*: assigned more than
    once at module scope, or assigned anywhere under a ``global``
    declaration.  Single-assignment module constants don't count."""
    counts: dict[str, int] = {}
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = [t for t in stmt.targets if isinstance(t, ast.Name)]
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)) \
                and isinstance(stmt.target, ast.Name):
            targets = [stmt.target]
        for t in targets:
            counts[t.id] = counts.get(t.id, 0) + 1
    rebound = {n for n, c in counts.items() if c > 1}
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            rebound.update(n for n in node.names if n in counts)
    return rebound


class TraceSafetyRule(Rule):
    code = "SF002"
    name = "trace-safety"
    summary = ("no wall-clock, print, .item() host syncs, or mutable-"
               "global capture inside jit/pmap-traced functions")

    def check_file(self, file, project):
        imports = import_map(file.tree)
        jitted = _jitted_names(file.tree, imports)
        rebound = _rebound_globals(file.tree)
        parents = parent_map(file.tree)

        traced_roots = []
        for node in ast.walk(file.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if (node.name in jitted
                        or any(_decorator_traces(d, imports)
                               for d in node.decorator_list)):
                    traced_roots.append(node)

        seen: set[ast.AST] = set()
        for root in traced_roots:
            for node in ast.walk(root):
                if node in seen:
                    continue
                seen.add(node)
                yield from self._check_node(file, node, root, imports,
                                            rebound, parents)

    def _check_node(self, file, node, root, imports, rebound, parents):
        if isinstance(node, ast.Call):
            c = call_canonical(node, imports)
            if c in _CLOCKS:
                yield self.diag(
                    file, node,
                    f"{c}() inside a traced function runs once at trace "
                    "time and is constant-folded into the program — move "
                    "wall-clock reads to the host loop")
            elif isinstance(node.func, ast.Name) and node.func.id == "print":
                yield self.diag(
                    file, node,
                    "print() inside a traced function fires only at trace "
                    "time — use jax.debug.print or log on the host")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield self.diag(
                    file, node,
                    ".item() inside a traced function forces a device->"
                    "host sync and fails under jit — keep values as arrays")
        elif isinstance(node, ast.Global):
            yield self.diag(
                file, node,
                "`global` mutation inside a traced function runs at trace "
                "time only — thread state through arguments instead")
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in rebound:
            if not self._is_local(node, root, parents):
                yield self.diag(
                    file, node,
                    f"traced function reads mutable module global "
                    f"'{node.id}' — its value is captured at trace time "
                    "and later rebinds are silently ignored (resolve it "
                    "before tracing and close over the resolved value)")

    @staticmethod
    def _is_local(name: ast.Name, root, parents) -> bool:
        """True when ``name`` is bound locally in any function scope
        between the use and the traced root (param or assignment)."""
        fn = name
        while fn is not None and fn is not parents.get(root):
            fn = parents.get(fn)
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
                args = fn.args
                params = [a.arg for a in (args.posonlyargs + args.args
                                          + args.kwonlyargs)]
                if args.vararg:
                    params.append(args.vararg.arg)
                if args.kwarg:
                    params.append(args.kwarg.arg)
                if name.id in params:
                    return True
                if not isinstance(fn, ast.Lambda):
                    for sub in ast.walk(fn):
                        if isinstance(sub, (ast.Assign, ast.AugAssign,
                                            ast.AnnAssign)):
                            tgts = (sub.targets
                                    if isinstance(sub, ast.Assign)
                                    else [sub.target])
                            for t in tgts:
                                if isinstance(t, ast.Name) \
                                        and t.id == name.id:
                                    return True
                        elif isinstance(sub, (ast.For, ast.AsyncFor)) \
                                and isinstance(sub.target, ast.Name) \
                                and sub.target.id == name.id:
                            return True
        return False
