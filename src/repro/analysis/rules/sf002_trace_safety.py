"""SF002 — trace safety (interprocedural since sfcheck v2).

A function handed to ``jax.jit`` runs its Python body *once per trace*,
not once per step.  Host-state reads inside it are frozen into the
compiled program (wall-clock, mutable module globals — exactly the
per-trace backend sniffing PR 4 had to remove from the kernel layer) or
force a device→host sync that breaks async dispatch (``.item()``), or
simply never fire again (``print``).  All of these look correct on the
first step and silently diverge later.

A function counts as *traced* when it is in the whole-program
**called-under-jit** set (:class:`repro.analysis.dataflow.ProjectDataflow`):

* decorated with ``jax.jit`` / ``jax.pmap`` (bare, ``@jax.jit(...)`` or
  via ``functools.partial(jax.jit, ...)``), or
* passed by name to a ``jax.jit(...)`` / ``jax.pmap(...)`` call anywhere
  in the project, or
* reachable from either through the project call graph — helper modules
  included, which is how PR 4's backend sniffing actually hid: the
  global read sat in ``ops.py``, the jit decorator in ``subcge.py``.

Inside traced bodies (nested defs and lambdas included) the rule flags
``time.*`` clock calls, ``print(...)``, ``.item()``, ``global`` /
``nonlocal`` mutation, and reads of module-level *rebound* globals —
a global assigned more than once, or assigned under a ``global``
declaration, is mutable state whose value the trace captures silently.
Rebound-global reads are judged against the *defining file's* globals,
so a cross-module helper is checked in its own module's terms.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import call_canonical

_CLOCKS = {"time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
           "time.perf_counter", "time.perf_counter_ns"}


class TraceSafetyRule(Rule):
    code = "SF002"
    name = "trace-safety"
    summary = ("no wall-clock, print, .item() host syncs, or mutable-"
               "global capture inside (transitively) jit-traced functions")

    def check_project(self, project):
        df = project.dataflow()
        seen: dict[str, set[int]] = {}
        for fi in df.functions():
            if not df.is_traced(fi):
                continue
            # nested defs of a traced function are walked with their root;
            # skip them so each node is checked (and reported) once
            anc = fi.parent
            ancestor_traced = False
            while anc is not None:
                if df.is_traced(anc):
                    ancestor_traced = True
                    break
                anc = anc.parent
            if ancestor_traced:
                continue
            fsum = fi.fsum
            marks = seen.setdefault(fsum.file.rel, set())
            for node in ast.walk(fi.node):
                if id(node) in marks:
                    continue
                marks.add(id(node))
                yield from self._check_node(
                    fsum.file, node, fi.node, fsum.imports,
                    fsum.rebound_globals, fsum.parents)

    def _check_node(self, file, node, root, imports, rebound, parents):
        if isinstance(node, ast.Call):
            c = call_canonical(node, imports)
            if c in _CLOCKS:
                yield self.diag(
                    file, node,
                    f"{c}() inside a traced function runs once at trace "
                    "time and is constant-folded into the program — move "
                    "wall-clock reads to the host loop")
            elif isinstance(node.func, ast.Name) and node.func.id == "print":
                yield self.diag(
                    file, node,
                    "print() inside a traced function fires only at trace "
                    "time — use jax.debug.print or log on the host")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield self.diag(
                    file, node,
                    ".item() inside a traced function forces a device->"
                    "host sync and fails under jit — keep values as arrays")
        elif isinstance(node, ast.Global):
            yield self.diag(
                file, node,
                "`global` mutation inside a traced function runs at trace "
                "time only — thread state through arguments instead")
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in rebound:
            if not self._is_local(node, root, parents):
                yield self.diag(
                    file, node,
                    f"traced function reads mutable module global "
                    f"'{node.id}' — its value is captured at trace time "
                    "and later rebinds are silently ignored (resolve it "
                    "before tracing and close over the resolved value)")

    @staticmethod
    def _is_local(name: ast.Name, root, parents) -> bool:
        """True when ``name`` is bound locally in any function scope
        between the use and the traced root (param or assignment)."""
        fn = name
        while fn is not None and fn is not parents.get(root):
            fn = parents.get(fn)
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
                args = fn.args
                params = [a.arg for a in (args.posonlyargs + args.args
                                          + args.kwonlyargs)]
                if args.vararg:
                    params.append(args.vararg.arg)
                if args.kwarg:
                    params.append(args.kwarg.arg)
                if name.id in params:
                    return True
                if not isinstance(fn, ast.Lambda):
                    for sub in ast.walk(fn):
                        if isinstance(sub, (ast.Assign, ast.AugAssign,
                                            ast.AnnAssign)):
                            tgts = (sub.targets
                                    if isinstance(sub, ast.Assign)
                                    else [sub.target])
                            for t in tgts:
                                if isinstance(t, ast.Name) \
                                        and t.id == name.id:
                                    return True
                        elif isinstance(sub, (ast.For, ast.AsyncFor)) \
                                and isinstance(sub.target, ast.Name) \
                                and sub.target.id == name.id:
                            return True
        return False
