"""SF010 — sender-step epoch flow.

PR 2's bug, promoted to a lint error.  A flooded SeedFlood message
regenerates on the receiver from ``(seed, coef, sender_step)``: the
sender's step selects the τ-epoch, and the τ-epoch selects the subspace
the update lives in.  The original replay path substituted the
*receiver's* step for the sender's (``np.where(cfs != 0, t, PAD)``) —
bitwise correct while both sat in the same epoch, silently wrong the
moment a replay crossed a subspace-refresh boundary under delayed
flooding or churn catch-up.  No error is ever raised; consensus just
drifts.

In ``src/repro/dtrain``, ``src/repro/sim`` and ``src/repro/serve`` the
rule checks every epoch-aware reconstruction call
(``epoch_slots(steps, ...)`` / ``apply_messages_epoch(..., steps, ...)``)
with the local value-flow engine (:class:`repro.analysis.dataflow
.LocalFlows`):

* **receiver-step substitution** — the ``steps`` argument has an origin
  that reaches the call through a scalar-substitution constructor
  (``np.where`` branch, ``np.full`` fill, ternary) and is not itself
  step-data (a ``*step*``-named parameter/attribute, or an ALL_CAPS
  padding constant).  That is the PR 2 shape: payload slots overwritten
  with a receiver-local scalar.
* **no sender steps at all** — the ``steps`` argument has no step-named
  origin anywhere: whatever is flowing in, it is not the payload's
  ``steps`` vector.
* **dropped payload steps** — a function reads a flood payload's
  ``.seeds`` *and* ``.coefs`` but never touches its ``.steps``: the
  reconstruction it feeds cannot be epoch-correct, whichever call it
  ends at.
* **epoch-less replay in a step-aware context** — a call to the
  epoch-less ``apply_messages(...)`` from a function that has sender
  steps in hand (reads a ``.steps`` attribute): the epoch-aware variant
  exists precisely so those steps are not dropped on the floor.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import call_canonical, dotted

#: canonical name -> index of the sender-steps positional argument.
EPOCH_CALLS = {
    "repro.core.subcge.epoch_slots": 0,
    "repro.core.subcge.apply_messages_epoch": 6,
}
#: The epoch-less reconstruction (correct only in step-free contexts).
FLAT_CALLS = {"repro.core.subcge.apply_messages"}

_STEP_NAMES = {"st", "sts", "stp", "ts"}


def _steplike(label: str) -> bool:
    return "step" in label.lower() or label.lower() in _STEP_NAMES


def _is_pad_const(label: str) -> bool:
    stripped = label.lstrip("_")
    return bool(stripped) and stripped == stripped.upper()


class EpochFlowRule(Rule):
    code = "SF010"
    name = "epoch-flow"
    summary = ("flood payload sender steps must reach epoch_slots/"
               "apply_messages_epoch unsubstituted on every replay path")

    def _in_scope(self, file) -> bool:
        return file.top == "src" and (file.in_dir("dtrain")
                                      or file.in_dir("sim")
                                      or file.in_dir("serve"))

    def check_project(self, project):
        df = project.dataflow()
        for fsum in df.file_summaries():
            if not self._in_scope(fsum.file):
                continue
            for fi in fsum.functions:
                yield from self._check_epoch_args(df, fsum, fi)
                yield from self._check_dropped_steps(fsum, fi)
                yield from self._check_flat_replay(fsum, fi)

    # -- the steps argument of epoch-aware calls -------------------------------

    def _check_epoch_args(self, df, fsum, fi):
        for call in fi.calls:
            c = call_canonical(call, fsum.imports)
            tail = c.rsplit(".", 1)[-1] if c else None
            pos = None
            for canon, p in EPOCH_CALLS.items():
                if c == canon or tail == canon.rsplit(".", 1)[-1]:
                    pos = p
                    break
            if pos is None:
                continue
            steps_arg = None
            if pos < len(call.args):
                steps_arg = call.args[pos]
            for kw in call.keywords:
                if kw.arg == "steps":
                    steps_arg = kw.value
            if steps_arg is None:
                continue
            flows = df.flows(fi)
            origins = flows.origins(steps_arg)
            named = [o for o in origins if o.kind in ("param", "attr",
                                                      "global")]
            substituted = [
                o for o in named
                if o.subst and not _steplike(o.label)
                and not _is_pad_const(o.label)]
            for o in substituted:
                yield self.diag(
                    fsum.file, steps_arg,
                    f"sender-steps argument of {tail}() carries "
                    f"'{o.label}' through a scalar-substitution "
                    "(np.where/np.full/ternary) — substituting a "
                    "receiver-local value for the payload's sender steps "
                    "replays across a τ boundary in the wrong subspace "
                    "(the PR 2 bug); thread the payload's steps through "
                    "unmodified")
            if named and not any(_steplike(o.label) for o in named):
                labels = sorted({o.label for o in named})
                yield self.diag(
                    fsum.file, steps_arg,
                    f"sender-steps argument of {tail}() has no step-data "
                    f"origin (flows from {', '.join(labels)}) — the "
                    "payload's steps vector never reaches the epoch "
                    "computation on this path")

    # -- payloads consumed without their steps ---------------------------------

    def _check_dropped_steps(self, fsum, fi):
        bases: dict[str, set[str]] = {}
        sites: dict[str, ast.AST] = {}
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, ast.Load) \
                    and node.attr in ("seeds", "coefs", "steps"):
                base = dotted(node.value)
                if base is None:
                    continue
                bases.setdefault(base, set()).add(node.attr)
                sites.setdefault(base, node)
        for base in sorted(bases):
            got = bases[base]
            if {"seeds", "coefs"} <= got and "steps" not in got:
                yield self.diag(
                    fsum.file, sites[base],
                    f"'{base}' has its .seeds and .coefs consumed but "
                    ".steps is never read — an epoch-correct replay needs "
                    "the sender steps; without them the reconstruction "
                    "regenerates the receiver's subspace, not the "
                    "sender's")

    # -- epoch-less replay where sender steps are in hand ----------------------

    def _check_flat_replay(self, fsum, fi):
        has_steps = any(
            isinstance(node, ast.Attribute) and node.attr == "steps"
            and isinstance(node.ctx, ast.Load)
            for node in ast.walk(fi.node))
        if not has_steps:
            return
        for call in fi.calls:
            c = call_canonical(call, fsum.imports)
            if c in FLAT_CALLS:
                yield self.diag(
                    fsum.file, call,
                    "epoch-less apply_messages() in a function that holds "
                    "sender steps — use apply_messages_epoch/epoch_slots "
                    "so the steps select each message's τ-epoch subspace "
                    "instead of being dropped")
