"""SF009 — jit-cache-key completeness.

The serving and simulation layers keep *dict-keyed jit caches*: one
compiled program per padded shape — ``self._prefill_fns[(Bg, T)]``,
``self._decode_fns[bucket]``, the bridge's ``self._fold_fns[(K, E)]``.
The contract is that the key captures **everything trace-affecting**
that varies between cache entries.  Two ways to get this wrong:

* a factory parameter that shapes the traced program is left out of the
  key — two different shapes collide on one entry and the second caller
  silently runs the first caller's program (wrong padding, wrong
  output);
* the traced closure reads ``self.<attr>`` where ``<attr>`` is
  *reassigned outside __init__* — a cache hit replays a program
  compiled against a stale value of that attribute (the cache-shaped
  cousin of PR 4's trace-time backend capture).

The rule recognizes the cache idiom inside ``src/repro/dtrain``,
``src/repro/sim`` and ``src/repro/serve``: a scope where a ``jax.jit``
product is stored into a subscript (directly or via a local name).  For
each such cache it checks (a) every factory parameter is part of the
key expression, and (b) every ``self.<attr>`` the jitted closure reads
is init-constant (assigned only in ``__init__``), part of the key, or a
call-time argument.  Parameters/attrs whose terminal name marks them as
the cache dict itself (the subscript base) are exempt.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule
from repro.analysis.rules.common import walk_scope

_INIT_METHODS = {"__init__", "__post_init__"}


def _key_names(key_expr) -> set[str]:
    return {n.id for n in ast.walk(key_expr) if isinstance(n, ast.Name)}


class CacheKeyRule(Rule):
    code = "SF009"
    name = "jit-cache-key"
    summary = ("dict-keyed jit caches in dtrain/, sim/ and serve/ must key "
               "on every trace-affecting factory param and mutable attr")

    def _in_scope(self, file) -> bool:
        return file.top == "src" and (file.in_dir("dtrain")
                                      or file.in_dir("sim")
                                      or file.in_dir("serve"))

    def check_project(self, project):
        df = project.dataflow()
        for fsum in df.file_summaries():
            if not self._in_scope(fsum.file):
                continue
            for fi in fsum.functions:
                yield from self._check_function(df, fsum, fi)

    def _check_function(self, df, fsum, fi):
        caches = self._caches(fsum, fi)
        if not caches:
            return
        attr_writers = None
        for jit_call, store in caches:
            key_names = _key_names(store.slice)
            # (a) factory params must all reach the key
            for p in fi.params:
                if p == "self" or p.startswith("_"):
                    continue
                if p not in key_names:
                    yield self.diag(
                        fsum.file, store,
                        f"jit cache key {ast.unparse(store.slice)!r} omits "
                        f"factory parameter '{p}' — two calls differing "
                        "only in it collide on one compiled program "
                        "(stale shape/config); add it to the key")
            # (b) mutable self-attrs read by the traced closure
            if fi.cls is None:
                continue
            if attr_writers is None:
                attr_writers = self._attr_writers(fsum, fi.cls)
            cache_base = (store.value.attr
                          if isinstance(store.value, ast.Attribute)
                          else None)
            for attr, site in self._closure_attr_reads(fi):
                writers = attr_writers.get(attr, [])
                mutators = [m for m in writers if m not in _INIT_METHODS]
                if not mutators or attr == cache_base:
                    continue
                if attr in key_names:
                    continue
                yield self.diag(
                    fsum.file, site,
                    f"jit cache factory reads self.{attr}, which "
                    f"{'/'.join(sorted(set(mutators)))} reassigns — a "
                    "cache hit replays a program compiled against a stale "
                    "value; include it in the key or pass it as a traced "
                    "argument")

    # -- cache recognition -----------------------------------------------------

    def _caches(self, fsum, fi):
        """(jit call, subscript-store Assign target) pairs: jit products
        stored into a dict, directly or via a local name."""
        jit_assigns: dict[str, ast.Call] = {}
        direct: list[tuple[ast.Call, ast.Subscript]] = []
        stores: list[tuple[ast.Subscript, str]] = []
        for node in walk_scope(fi.node):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt, val = node.targets[0], node.value
            is_jit = isinstance(val, ast.Call) and self._is_jit(val, fsum)
            if isinstance(tgt, ast.Subscript):
                if is_jit:
                    direct.append((val, tgt))
                elif isinstance(val, ast.Name):
                    stores.append((tgt, val.id))
            elif isinstance(tgt, ast.Name) and is_jit:
                jit_assigns[tgt.id] = val
        out = list(direct)
        for tgt, name in stores:
            if name in jit_assigns:
                out.append((jit_assigns[name], tgt))
        return out

    @staticmethod
    def _is_jit(call, fsum) -> bool:
        from repro.analysis.dataflow import is_jit_call
        return is_jit_call(call, fsum.imports)

    # -- closure attr reads / class attr writes --------------------------------

    def _closure_attr_reads(self, fi):
        """``self.<attr>`` loads anywhere in the factory — in its own
        scope (captured by the closure at build time) or inside nested
        defs/lambdas (read at trace time) — both frozen into the cached
        program."""
        out = []
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, ast.Load) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "self":
                out.append((node.attr, node))
        return out

    def _attr_writers(self, fsum, cls) -> dict[str, list[str]]:
        """attr -> method names that assign ``self.<attr>`` in this class."""
        out: dict[str, list[str]] = {}
        for stmt in cls.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(stmt):
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                    targets = [node.target]
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        out.setdefault(t.attr, []).append(stmt.name)
        return out
