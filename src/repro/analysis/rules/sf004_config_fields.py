"""SF004 — config-field consumption.

PR 3's post-mortem: four DTrainConfig knobs (``momentum``,
``choco_density``, …) were silently ignored by most methods for months
— a run *looked* configured but trained something else.  The runtime
fix was ``validate_config``'s per-method rejection table; this rule is
the static half: **every field on the user-facing config dataclasses
must be read somewhere in src/**, as an attribute (``cfg.field``) or by
name in the rejection table / a ``getattr`` string.  A knob nobody
reads can never change behavior, so either it is dead or — worse — its
consumer was refactored away and runs are quietly misconfigured.

Cross-module pass: collect annotated fields of the config classes, then
scan every file under ``src/`` for attribute loads and string constants
naming them.  Underscore-prefixed names and ``ClassVar`` annotations
are exempt.
"""
from __future__ import annotations

import ast

from repro.analysis.rules import Rule

#: The user-facing config surfaces (DESIGN.md §4/§3/§10): the classes whose
#: fields are promises to the user that a knob does something.
CONFIG_CLASSES = ("DTrainConfig", "SubCGEConfig", "PodConfig", "ServeConfig")


class ConfigFieldsRule(Rule):
    code = "SF004"
    name = "config-field-consumption"
    summary = ("every DTrainConfig/SubCGEConfig/PodConfig/ServeConfig field "
               "must be read somewhere in src/ (attribute or rejection-table "
               "name)")

    def check_project(self, project):
        # fields: (class, field, file, node) from class bodies under src/
        fields = []
        for cls_name in CONFIG_CLASSES:
            for f, node in project.class_index().get(cls_name, ()):
                if f.top != "src":
                    continue
                for stmt in node.body:
                    if not (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)):
                        continue
                    name = stmt.target.id
                    ann = ast.unparse(stmt.annotation)
                    if name.startswith("_") or "ClassVar" in ann:
                        continue
                    fields.append((cls_name, name, f, stmt))
        if not fields:
            return

        # consumption scan over the cached per-file summaries: attribute
        # LOADs (stores/keywords are writes, not reads) and exact-identifier
        # string constants ("momentum" in the rejection table counts, prose
        # mentions in docstrings don't — they are never a single identifier).
        df = project.dataflow()
        attr_reads: set[str] = set()
        str_consts: set[str] = set()
        for fsum in df.file_summaries():
            if fsum.file.top != "src":
                continue
            attr_reads |= fsum.attr_loads
            str_consts |= fsum.str_consts

        for cls_name, name, f, stmt in fields:
            if name in attr_reads or name in str_consts:
                continue
            yield self.diag(
                f, stmt,
                f"{cls_name}.{name} is never read in src/ — a knob nobody "
                "consumes silently does nothing; wire it up, name it in "
                "validate_config's rejection table, or delete it")
