"""The event-driven counterpart of the synchronous Trainer (DESIGN.md §9).

Same Method, same Setup, same RunResult — only the clock changes: instead
of ``for t in range(steps)`` with a barrier per step, a discrete-event loop
pops ``STEP < DELIVER < CHURN`` events off a virtual-time priority queue.
Each client steps at its own trace rate; flood messages arrive per edge
after propagation + serialization delay and are folded in through the same
epoch-grouped ``apply_inbox`` the synchronous loop uses (the sender-step
replay of DESIGN.md §3 is what makes arbitrarily stale arrival exact).

Clients finishing the same step at the same virtual time form a *cohort*
processed as one batched dispatch.  With a homogeneous zero-latency trace
every cohort is the full swarm and the run reproduces the synchronous
Trainer bitwise (``tests/test_sim.py`` pins loss curve, byte ledger, and
final parameters); heterogeneous traces degrade gracefully to per-client
cohorts with the same jit programs.

Method contracts are reused, not extended:

* the ``active`` argument of ``local_step`` carries a float weight vector —
  1.0 on cohort members plus the ``n_online - |cohort|`` remainder on the
  lowest cohort member, so SeedFlood's ``n_eff = sum(active)`` equals the
  online population exactly (integer-valued floats, exact sums) while
  non-cohort rows keep zero weight and stay bitwise frozen;
* gossip methods get the plain boolean cohort mask (their freeze guard
  already handles partial masks) and mixing stays a barrier — clients wait
  at mix steps, run free between them.

Churn schedules are defined on step indices; the event loop maps index
``T`` to virtual time ``T * ref`` (``ref`` = ``sim_churn_step_s`` or the
trace's median step time), ranked after same-time STEP/DELIVER events so
the cohort completing at that instant still ran pre-mutation — the
synchronous "churn lands at the start of the step" ordering.

The run always drains: after the last cohort, trailing flood frontiers are
released and every delivered message applied, so the final model state is
the fully-mixed one (compare with ``drain=True`` synchronous runs).
"""
from __future__ import annotations

import time

import numpy as np

from repro.dtrain.api import (Method, RunResult, Setup, active_consensus,
                              log_step_loss)
from repro.sim import events
from repro.sim.async_transport import AsyncFloodTransport
from repro.sim.events import EventQueue
from repro.sim.traces import TraceSet
from repro.topology.dynamic import ChurnSchedule


class EventTrainer:
    """Drives one trace-clocked asynchronous run of ``method``."""

    def __init__(self, cfg, setup: Setup, method: Method, transport,
                 trace: TraceSet, churn: ChurnSchedule | None = None,
                 init_order=None):
        if churn is not None and not isinstance(transport, AsyncFloodTransport):
            raise ValueError("event-driven churn needs the flood substrate "
                             "(gossip mixing is a barrier over all clients)")
        self.cfg = cfg
        self.setup = setup
        self.method = method
        self.transport = transport
        self.trace = trace
        self.churn = churn
        # initial-event insertion order; results must not depend on it
        # (tests permute it) — kept as a knob only for that test.
        self.init_order = list(init_order) if init_order is not None \
            else list(range(cfg.n_clients))

    # -- helpers ---------------------------------------------------------------

    def _maybe_eval(self, idx: int, state) -> None:
        """Eval cadence on step *indices*: index ``t`` fires once the swarm
        reaches step ``t`` — the synchronous eval at the end of step
        ``t - 1`` — regardless of the virtual time that took."""
        ee = self.cfg.eval_every
        if not ee or idx == 0 or idx % ee or idx in self._evaluated:
            return
        self._evaluated.add(idx)
        stacked = self.method.params_of(state)
        self._acc_curve.append((idx, self.setup.gmp(stacked)))
        self._consensus_curve.append(
            (idx, active_consensus(stacked, self.transport.active_mask())))

    def _pop_cohort(self, first: events.Event, q: EventQueue,
                    gen: list[int]) -> list[int]:
        """Coalesce every queued STEP event sharing ``(time, step)`` with
        ``first`` (stale generations dropped) — one batched dispatch."""
        cohort = [first.client]
        while True:
            nxt = q.peek()
            if (nxt is None or nxt.rank != events.RANK_STEP
                    or nxt.time != first.time or nxt.step != first.step):
                break
            nxt = q.pop()
            if nxt.client_gen == gen[nxt.client]:
                cohort.append(nxt.client)
        return sorted(cohort)

    def _schedule_step(self, q: EventQueue, i: int, t: int, now: float,
                       gen: list[int], next_step: list[int]) -> None:
        if t < self.cfg.steps:
            finish = self.trace.finish_time(i, now,
                                            self.trace.compute_time(i, t))
            q.push(events.step_event(finish, i, t, gen[i]))
        next_step[i] = t

    def _apply_churn(self, ev: events.Event, q: EventQueue, state,
                     gen: list[int], next_step: list[int]):
        """Map one churn step index onto the live topology.  Before mutating,
        every delivered-but-unapplied message is folded in: the synchronous
        loop applied the previous step's exchange before this churn fired,
        and a departing node must not take an unapplied inbox offline."""
        inbox = self.transport.pop_inbox(list(range(self.cfg.n_clients)),
                                         ev.step)
        if inbox is not None:
            state = self.method.apply_inbox(state, inbox)
        before = np.array(self.transport.active_mask(), bool)
        self.transport.apply_churn(self.churn.events_at(ev.step))
        after = np.array(self.transport.active_mask(), bool)
        for i in np.flatnonzero(before & ~after):
            gen[int(i)] += 1           # cancel the in-flight STEP event
        for i in np.flatnonzero(after & ~before):
            i = int(i)
            gen[i] += 1
            # a rejoiner resumes at the current virtual step — never re-runs
            # steps it already took, never back-fills steps it slept through
            self._schedule_step(q, i, max(next_step[i], ev.step), ev.time,
                                gen, next_step)
        return state

    # -- the loop --------------------------------------------------------------

    def run(self) -> RunResult:
        cfg, s, method, transport = self.cfg, self.setup, self.method, \
            self.transport
        n = cfg.n_clients
        state = method.init(s)
        transport.bind(method.initial_payload(state))
        t0 = time.time()

        loss_curve: list[float] = []
        self._acc_curve: list[tuple[int, float]] = []
        self._consensus_curve: list[tuple[int, float]] = []
        self._evaluated: set[int] = set()
        loss_vs_vtime: list[tuple[float, float]] = []

        q = EventQueue()
        gen = [0] * n
        next_step = [0] * n
        for i in self.init_order:
            self._schedule_step(q, i, 0, 0.0, gen, next_step)
        if self.churn is not None:
            ref = cfg.sim_churn_step_s or self.trace.ref_step_s
            for T in sorted({ev.step for ev in self.churn.events}):
                q.push(events.churn_event(T * ref, T))

        is_flood = isinstance(transport, AsyncFloodTransport)
        done: dict[int, set[int]] = {}      # gossip barrier bookkeeping
        last_payload = None
        now = 0.0

        while q:
            ev = q.pop()
            now = ev.time
            if ev.rank == events.RANK_CHURN:
                state = self._apply_churn(ev, q, state, gen, next_step)
                continue
            if ev.rank == events.RANK_DELIVER:
                transport.deliver(ev, q)
                continue
            if ev.client_gen != gen[ev.client]:
                continue                    # cancelled by churn
            cohort = self._pop_cohort(ev, q, gen)
            t = ev.step

            if is_flood:
                inbox = transport.pop_inbox(cohort, t)
                if inbox is not None:
                    state = method.apply_inbox(state, inbox)
                self._maybe_eval(t, state)

                mask = np.array(transport.active_mask(), bool)
                w = np.zeros(n, np.float64)
                w[cohort] = 1.0
                w[cohort[0]] += max(int(mask.sum()) - len(cohort), 0)
                state, outbox = method.local_step(state, s.batches(t), w, t)
                cmask = np.zeros(n, bool)
                cmask[cohort] = True
                log_step_loss(loss_curve, np.asarray(outbox.losses),
                              cmask[:len(outbox.losses)])
                loss_vs_vtime.append((now, loss_curve[-1]))

                for i, msg in outbox.payload:
                    transport.emit(i, msg, now, q)
                for i in cohort:
                    transport.release(i, now, q)
                transport.merge_deferred(cohort)
                for i in cohort:
                    self._schedule_step(q, i, t + 1, now, gen, next_step)
            else:
                cmask = np.zeros(n, bool)
                cmask[cohort] = True
                state, outbox = method.local_step(state, s.batches(t),
                                                  cmask, t)
                log_step_loss(loss_curve, np.asarray(outbox.losses),
                              cmask[:len(outbox.losses)])
                loss_vs_vtime.append((now, loss_curve[-1]))
                last_payload = outbox.payload
                done.setdefault(t, set()).update(cohort)

                if (t + 1) % transport.every:
                    for i in cohort:
                        self._schedule_step(q, i, t + 1, now, gen, next_step)
                    if len(done[t]) == n:
                        self._maybe_eval(t + 1, state)
                else:
                    # mixing is a barrier: finished clients idle at the mix
                    # point until the last straggler's step-t model exists
                    for i in cohort:
                        next_step[i] = t + 1
                    if len(done[t]) == n:
                        mixed, delay = transport.mix(
                            last_payload, t, transport.active_mask())
                        state = method.apply_inbox(state, mixed)
                        self._maybe_eval(t + 1, state)
                        for i in range(n):
                            self._schedule_step(q, i, t + 1, now + delay,
                                                gen, next_step)

        if is_flood:
            # always drain: release trailing frontiers until quiescent, then
            # fold in everything still delivered-but-unapplied
            while transport.final_release(now, q):
                while q:
                    ev = q.pop()
                    now = ev.time
                    if ev.rank == events.RANK_DELIVER:
                        transport.deliver(ev, q)
            inbox = transport.final_flush(cfg.steps)
            if inbox is not None:
                state = method.apply_inbox(state, inbox)
        self._maybe_eval(cfg.steps, state)

        mask = transport.active_mask()
        stacked = method.params_of(state)
        stats = transport.stats()
        extra = {"n_params": s.n_params, **stats,
                 "consensus_curve": self._consensus_curve,
                 "step_wall_s": [],
                 "virtual_time_s": now,
                 "loss_vs_virtual_time": loss_vs_vtime,
                 **method.result_extra(state)}
        return RunResult(
            method=method.label(stats), gmp=s.gmp(stacked),
            loss_curve=loss_curve, acc_curve=self._acc_curve,
            bytes_per_edge=transport.ledger.per_edge,
            total_bytes=transport.ledger.total_bytes,
            consensus_error=active_consensus(stacked, mask),
            wall_s=time.time() - t0, extra=extra)


def barrier_schedule(trace: TraceSet, steps: int) -> list[float]:
    """Per-step completion times of the synchronous-barrier baseline on the
    same trace: every step waits for the slowest client (episodes included).
    ``BENCH_async.json`` measures async speedup against this."""
    times = []
    now = 0.0
    for t in range(steps):
        now = max(trace.finish_time(i, now, trace.compute_time(i, t))
                  for i in range(trace.n))
        times.append(now)
    return times


def time_to_loss(curve: list[tuple[float, float]], target: float) -> float:
    """First virtual time at which the running-min loss crosses ``target``
    (``inf`` if never) — the wall-clock-to-loss metric of the async bench."""
    best = float("inf")
    for vt, loss in curve:
        best = min(best, loss)
        if best <= target:
            return vt
    return float("inf")
