"""Per-client compute/bandwidth traces for the event-driven simulator.

A :class:`TraceSet` fixes, for every client, a per-step compute time, a
link bandwidth, and a propagation latency, plus optional timed *episodes*
(stragglers and preemptions) that modulate compute progress.  Traces are
plain frozen data — hashable, JSON round-trippable — so a heterogeneous
swarm experiment is exactly reproducible from its config.

Delay model (DESIGN.md §9): a batch of ``nbytes`` flood bytes sent from
``i`` to ``j`` arrives after

    latency_s[i] + latency_s[j] + extra_latency + nbytes * 8 / min(bw_i, bw_j)

where the byte count is exactly what the :class:`~repro.core.messages.
CommLedger` charges for the send (``len(msgs) * MESSAGE_BYTES``) — virtual
time and the paper's byte accounting derive from the same number.  Infinite
bandwidth (JSON ``null``) zeroes the serialization term; the all-defaults
:meth:`TraceSet.constant` trace is therefore the homogeneous zero-latency
trace under which the event loop must reproduce the synchronous Trainer
bitwise.

Episode semantics: within ``[t0, t1)`` a client's compute progresses at
rate ``1/factor`` (``straggle``) or stops entirely (``preempt``); progress
is integrated piecewise by :meth:`TraceSet.finish_time`.  Episodes of one
client must not overlap.
"""
from __future__ import annotations

import dataclasses
import json
import math
import statistics

import numpy as np

EPISODE_KINDS = ("straggle", "preempt")


@dataclasses.dataclass(frozen=True)
class Episode:
    """One timed compute disruption of a single client."""
    client: int
    t0: float                  # virtual seconds, inclusive
    t1: float                  # virtual seconds, exclusive
    kind: str                  # "straggle" | "preempt"
    factor: float = 1.0        # straggle: slowdown multiplier (>= 1)

    def __post_init__(self):
        if self.kind not in EPISODE_KINDS:
            raise ValueError(f"unknown episode kind '{self.kind}' "
                             f"(have {EPISODE_KINDS})")
        if not self.t1 > self.t0 >= 0.0:
            raise ValueError(f"episode needs 0 <= t0 < t1, got "
                             f"[{self.t0}, {self.t1})")
        if self.kind == "straggle" and self.factor < 1.0:
            raise ValueError("straggle factor must be >= 1")

    @property
    def rate(self) -> float:
        """Compute progress per virtual second inside the episode."""
        return 0.0 if self.kind == "preempt" else 1.0 / self.factor


@dataclasses.dataclass(frozen=True)
class TraceSet:
    """Per-client compute/bandwidth/latency profile of one swarm."""
    compute_s: tuple[float, ...]        # base seconds per local step
    bandwidth_bps: tuple[float, ...]    # bits/s; math.inf = no serialization
    latency_s: tuple[float, ...]        # one-way propagation, per client
    episodes: tuple[Episode, ...] = ()

    def __post_init__(self):
        n = len(self.compute_s)
        if not (len(self.bandwidth_bps) == len(self.latency_s) == n > 0):
            raise ValueError("compute_s/bandwidth_bps/latency_s lengths differ")
        if any(c <= 0 for c in self.compute_s):
            raise ValueError("compute_s entries must be positive")
        if any(b <= 0 for b in self.bandwidth_bps):
            raise ValueError("bandwidth_bps entries must be positive")
        if any(ep.client not in range(n) for ep in self.episodes):
            raise ValueError("episode client out of range")
        for i in range(n):
            spans = sorted((ep.t0, ep.t1) for ep in self.episodes
                           if ep.client == i)
            for (_, a1), (b0, _) in zip(spans, spans[1:]):
                if b0 < a1:
                    raise ValueError(f"client {i} has overlapping episodes")

    @property
    def n(self) -> int:
        return len(self.compute_s)

    @property
    def ref_step_s(self) -> float:
        """Median per-step compute — the default virtual seconds one
        ChurnSchedule step index spans (``sim_churn_step_s`` overrides)."""
        return float(statistics.median(self.compute_s))

    # -- virtual-time arithmetic ----------------------------------------------

    def compute_time(self, client: int, step: int) -> float:
        """Base compute seconds of one local step (constant per client; the
        step argument keeps the signature ready for per-step traces)."""
        del step
        return self.compute_s[client]

    def finish_time(self, client: int, start: float, work_s: float) -> float:
        """Virtual time at which ``work_s`` seconds of full-rate compute
        starting at ``start`` completes, integrating episode rates."""
        t, remaining = start, work_s
        for ep in sorted((e for e in self.episodes if e.client == client),
                         key=lambda e: e.t0):
            if ep.t1 <= t:
                continue
            if ep.t0 > t:                      # full-rate gap before episode
                gap = ep.t0 - t
                if remaining <= gap:
                    return t + remaining
                t, remaining = ep.t0, remaining - gap
            span = ep.t1 - t
            if ep.rate > 0 and remaining <= span * ep.rate:
                return t + remaining / ep.rate
            t, remaining = ep.t1, remaining - span * ep.rate
        return t + remaining

    def edge_delay(self, i: int, j: int, nbytes: int,
                   extra_latency: float = 0.0) -> float:
        """Delivery delay of ``nbytes`` ledger-charged bytes over edge (i,j)."""
        lat = self.latency_s[i] + self.latency_s[j] + extra_latency
        bw = min(self.bandwidth_bps[i], self.bandwidth_bps[j])
        ser = 0.0 if math.isinf(bw) else nbytes * 8.0 / bw
        return lat + ser

    # -- builders -------------------------------------------------------------

    @classmethod
    def constant(cls, n: int, compute_s: float = 1.0,
                 bandwidth_bps: float = math.inf,
                 latency_s: float = 0.0) -> "TraceSet":
        """Homogeneous trace; all defaults = the zero-latency oracle trace."""
        return cls((float(compute_s),) * n, (float(bandwidth_bps),) * n,
                   (float(latency_s),) * n)

    @classmethod
    def two_speed(cls, n: int, fast_s: float = 1.0, slow_s: float = 4.0,
                  bandwidth_bps: float = math.inf,
                  latency_s: float = 0.0) -> "TraceSet":
        """First half of the swarm fast, second half slow — the benchmark's
        compute-heterogeneity shape (slow_s/fast_s = the heterogeneity ratio)."""
        comp = tuple(float(fast_s) if i < n - n // 2 else float(slow_s)
                     for i in range(n))
        return cls(comp, (float(bandwidth_bps),) * n, (float(latency_s),) * n)

    @classmethod
    def lognormal(cls, n: int, median_s: float = 1.0, sigma: float = 0.5,
                  seed: int = 0, bandwidth_bps: float = math.inf,
                  latency_s: float = 0.0) -> "TraceSet":
        """Lognormal-heterogeneous compute times (the SWARM-style long tail)."""
        rng = np.random.default_rng(seed)
        comp = median_s * np.exp(sigma * rng.standard_normal(n))
        return cls(tuple(float(c) for c in comp),
                   (float(bandwidth_bps),) * n, (float(latency_s),) * n)

    # -- JSON -----------------------------------------------------------------

    def to_json(self) -> dict:
        def bw(b: float):
            return None if math.isinf(b) else b
        return {
            "n": self.n,
            "compute_s": list(self.compute_s),
            "bandwidth_bps": [bw(b) for b in self.bandwidth_bps],
            "latency_s": list(self.latency_s),
            "episodes": [{"client": ep.client, "t0": ep.t0, "t1": ep.t1,
                          "kind": ep.kind, "factor": ep.factor}
                         for ep in self.episodes],
        }

    @classmethod
    def from_json(cls, d: dict) -> "TraceSet":
        comp = tuple(float(c) for c in d["compute_s"])
        n = int(d.get("n", len(comp)))
        if n != len(comp):
            raise ValueError(f"trace says n={n} but has {len(comp)} "
                             f"compute_s entries")
        bws = tuple(math.inf if b is None else float(b)
                    for b in d.get("bandwidth_bps", [None] * n))
        lats = tuple(float(x) for x in d.get("latency_s", [0.0] * n))
        eps = tuple(Episode(client=int(e["client"]), t0=float(e["t0"]),
                            t1=float(e["t1"]), kind=str(e["kind"]),
                            factor=float(e.get("factor", 1.0)))
                    for e in d.get("episodes", ()))
        return cls(comp, bws, lats, eps)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "TraceSet":
        with open(path) as f:
            return cls.from_json(json.load(f))


def as_trace(obj, n_clients: int) -> TraceSet:
    """Resolve ``DTrainConfig.trace`` — a TraceSet, a trace-JSON dict, or a
    path to one — and check it matches the swarm size."""
    if isinstance(obj, TraceSet):
        trace = obj
    elif isinstance(obj, dict):
        trace = TraceSet.from_json(obj)
    elif isinstance(obj, str):
        trace = TraceSet.load(obj)
    else:
        raise TypeError(f"trace must be a TraceSet, trace-JSON dict, or "
                        f"path, got {type(obj).__name__}")
    if trace.n != n_clients:
        raise ValueError(f"trace covers {trace.n} clients but the run has "
                         f"{n_clients}")
    return trace
