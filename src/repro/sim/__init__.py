"""Deterministic discrete-event simulation of asynchronous swarms (DESIGN.md §9)."""
from repro.sim.async_transport import (AsyncFloodTransport,
                                       AsyncGossipTransport, wrap_async)
from repro.sim.event_trainer import (EventTrainer, barrier_schedule,
                                     time_to_loss)
from repro.sim.events import Event, EventQueue
from repro.sim.traces import Episode, TraceSet, as_trace

__all__ = [
    "AsyncFloodTransport", "AsyncGossipTransport", "wrap_async",
    "EventTrainer", "barrier_schedule", "time_to_loss",
    "Event", "EventQueue",
    "Episode", "TraceSet", "as_trace",
]
