"""Deterministic discrete-event core of the asynchronous simulator (DESIGN.md §9).

A priority queue of timestamped events — no real clocks anywhere (SF001/
SF002 stay clean), so a run is a pure function of its config and bitwise
reproducible.  Three event kinds, ranked at equal virtual time:

    STEP(0) < DELIVER(1) < CHURN(2)

* ``STEP``    — a client finishes the compute of local step ``step``.  All
  STEP events sharing ``(time, step)`` form one *cohort* the EventTrainer
  processes as a single batched dispatch (with homogeneous traces the
  cohort is every online client, which is exactly one synchronous step).
* ``DELIVER`` — a batch of flood messages arrives at ``client`` over the
  edge from ``sender``, ``gen`` hops from its emission.  DELIVER outranks
  CHURN so a zero-latency delivery lands before a same-timestamp topology
  mutation — mirroring the synchronous loop, where step ``t``'s exchange
  completes before step ``t+1``'s churn events apply.
* ``CHURN``   — a :class:`~repro.topology.dynamic.ChurnSchedule` step index
  mapped onto virtual time.  Ranked last so the cohort completing at the
  same timestamp still ran on the pre-mutation topology.

**Tiebreak rule.** The heap is keyed on the *content* tuple
``(time, rank, step, gen, sender, client)`` with an insertion sequence
number as the final component.  Content fields order everything the
synchronous oracle orders (round structure via ``gen``, the per-round
``for i in range(n)`` send order via ``sender``); the sequence number only
separates events whose content coincides — and those are only ever pushed
by an earlier, already fully key-ordered cascade, so pop order is
independent of the order initial events were inserted (pinned by
``tests/test_sim.py``).
"""
from __future__ import annotations

import dataclasses
import heapq

RANK_STEP = 0
RANK_DELIVER = 1
RANK_CHURN = 2


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    rank: int
    client: int = -1       # STEP: stepping client; DELIVER: destination
    step: int = -1         # STEP / CHURN: step index
    gen: int = 0           # DELIVER: flood hop generation (1 = first hop)
    sender: int = -1       # DELIVER: forwarding client
    msgs: tuple = ()       # DELIVER: Message batch, emission-ordered
    client_gen: int = 0    # STEP: churn generation; stale events are skipped

    def key(self) -> tuple:
        return (self.time, self.rank, self.step, self.gen, self.sender,
                self.client)


def step_event(time: float, client: int, step: int,
               client_gen: int = 0) -> Event:
    return Event(time=time, rank=RANK_STEP, client=client, step=step,
                 client_gen=client_gen)


def deliver_event(time: float, dst: int, sender: int, gen: int,
                  msgs: tuple) -> Event:
    return Event(time=time, rank=RANK_DELIVER, client=dst, sender=sender,
                 gen=gen, msgs=msgs)


def churn_event(time: float, step: int) -> Event:
    return Event(time=time, rank=RANK_CHURN, step=step)


class EventQueue:
    """Min-heap over :meth:`Event.key` with an insertion-sequence tiebreak."""

    def __init__(self):
        self._heap: list[tuple[tuple, int, Event]] = []
        self._seq = 0

    def push(self, ev: Event) -> None:
        heapq.heappush(self._heap, (ev.key(), self._seq, ev))
        self._seq += 1

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Event | None:
        return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
