"""Async adapters over the synchronous Transport plugins (DESIGN.md §9).

The PR 3 seam holds: Methods are untouched and byte accounting stays inside
transports.  These adapters replace the round-synchronous ``exchange`` with
timestamped per-edge delivery:

* :class:`AsyncFloodTransport` wraps a :class:`~repro.core.transport.
  FloodTransport`'s per-message :class:`~repro.core.flood.FloodNetwork`
  (the bitset engine is round-synchronous by construction and is rejected).
  Emission floods hop by hop: each accepted batch is forwarded to every
  live neighbour as a DELIVER event delayed by the trace's propagation +
  serialization formula over exactly the bytes the ledger charges.  The
  per-(gen, sender) event ordering reproduces the synchronous round
  structure, so with a homogeneous zero-latency trace the pending-inbox
  sequence each client applies is bitwise the synchronous one.
* :class:`AsyncGossipTransport` wraps a :class:`~repro.core.transport.
  GossipTransport`: mixing is inherently a barrier, so the EventTrainer
  waits for every client to finish step ``t`` before mixing; the adapter
  charges the ledger through the wrapped ``exchange`` and converts the
  charged bytes into one mix delay.

Anti-entropy catch-up after churn lands in a *deferred* buffer that is
merged into a client's pending inbox only after its same-timestamp cohort
has applied + stepped — the synchronous loop's "catch-up rides in this
step's exchange" ordering.  The re-flood of caught-up messages sits in the
node's frontier and is released at its next emission, ahead of its fresh
message, matching the synchronous round-1 frontier order.
"""
from __future__ import annotations

import numpy as np

from repro.core import flood
from repro.core.messages import MESSAGE_BYTES
from repro.core.transport import (FloodInbox, FloodTransport, GossipTransport,
                                  TransportBase)
from repro.sim import events
from repro.sim.events import EventQueue
from repro.sim.traces import TraceSet


class AsyncFloodTransport(TransportBase):
    """Timestamped per-edge flooding over the reference flood engine."""

    kind = "flood"

    def __init__(self, inner: FloodTransport, trace: TraceSet,
                 extra_latency_s: float = 0.0):
        if not isinstance(inner.net, flood.FloodNetwork):
            raise ValueError(
                "the event engine needs the per-message flood engine; set "
                "flood_backend='python' (the numpy bitset engine is "
                "round-synchronous)")
        if inner.flood_k is not None:
            raise ValueError("flood_k has no meaning under per-edge "
                             "timestamped delivery")
        self.inner = inner
        self.net: flood.FloodNetwork = inner.net
        self.trace = trace
        self.extra_latency_s = extra_latency_s
        n = self.net.n
        # delivered-but-unapplied messages, in arrival order (float-sum order)
        self._pending: list[list] = [[] for _ in range(n)]
        # anti-entropy catch-up awaiting the post-cohort merge
        self._deferred: list[list] = [[] for _ in range(n)]

    @property
    def ledger(self):
        return self.net.ledger

    def active_mask(self) -> np.ndarray:
        return self.net.active_mask()

    def stats(self) -> dict:
        return self.inner.stats()

    # -- emission / delivery ---------------------------------------------------

    def emit(self, client: int, msg, now: float, queue: EventQueue) -> None:
        """A client's fresh message enters its own frontier (Algorithm 1
        block (C) — it already applied the update locally)."""
        del now, queue
        self.net.inject(client, msg)

    def release(self, client: int, now: float, queue: EventQueue) -> None:
        """Flush the client's frontier — queued anti-entropy re-floods first,
        then fresh injections — to all live neighbours as gen-1 deliveries."""
        st = self.net.states[client]
        if not st.frontier:
            return
        frontier, st.frontier = st.frontier, []
        self._forward(client, frontier, 1, now, queue)

    def _forward(self, src: int, msgs: list, gen: int, now: float,
                 queue: EventQueue) -> None:
        nbytes = len(msgs) * MESSAGE_BYTES
        batch = tuple(msgs)
        for j in self.net.topo.neighbors()[src]:
            self.net.ledger.send(nbytes, count=len(msgs))
            delay = self.trace.edge_delay(src, j, nbytes, self.extra_latency_s)
            queue.push(events.deliver_event(now + delay, dst=j, sender=src,
                                            gen=gen, msgs=batch))

    def deliver(self, ev: events.Event, queue: EventQueue) -> None:
        """Accept a delivery: dedup against S_i, append survivors to the
        pending inbox, and forward them one hop further.  Messages to an
        offline node or over a dead edge are lost in flight (anti-entropy
        recovers them on rejoin/heal)."""
        dst, topo = ev.client, self.net.topo
        if not topo.is_active(dst) or not topo.edge_live(ev.sender, dst):
            return
        st = self.net.states[dst]
        fresh = []
        for m in ev.msgs:
            if m.uid in st.seen:
                continue
            st.seen.add(m.uid)
            st.store[m.uid] = m
            self._pending[dst].append(m)
            fresh.append(m)
        if not fresh:
            return
        if ev.gen >= self.net.diameter:
            # hop budget: the synchronous engine floods `diameter` rounds
            # per exchange, so a last-hop accept waits in the frontier until
            # the node's next release (and is dropped uncharged if the node
            # departs first) — mirrored exactly, ledgers included
            st.frontier.extend(fresh)
        else:
            self._forward(dst, fresh, ev.gen + 1, ev.time, queue)

    # -- inbox / churn ---------------------------------------------------------

    def pop_inbox(self, cohort: list[int], t: int) -> FloodInbox | None:
        """Drain the cohort's pending messages into the padded ``(n, K)``
        matrices of the batched replay (non-cohort rows are zero-coefficient
        padding — exact no-ops)."""
        take = set(cohort)
        payloads = []
        for i in range(self.net.n):
            if i in take and self._pending[i]:
                f, self._pending[i] = self._pending[i], []
                payloads.append(
                    (np.asarray([m.seed for m in f], np.uint32),
                     np.asarray([m.coef for m in f], np.float32),
                     np.asarray([m.step for m in f], np.int32)))
            else:
                payloads.append((np.zeros(0, np.uint32),
                                 np.zeros(0, np.float32),
                                 np.zeros(0, np.int32)))
        sds, cfs, stp = flood.pad_payloads(payloads)
        if sds.shape[1] == 0:
            return None
        return FloodInbox(sds, cfs, stp, t)

    def apply_churn(self, evs) -> None:
        self.net.apply_churn(evs)
        for dst, msgs in enumerate(self.net.drain_catchup()):
            self._deferred[dst].extend(msgs)

    def merge_deferred(self, cohort: list[int]) -> None:
        """After a cohort applied + stepped, its anti-entropy catch-up joins
        the pending inbox — ahead of later deliveries, like the synchronous
        exchange prepends catch-up to the same step's padded matrices."""
        for i in cohort:
            if self._deferred[i]:
                self._pending[i] = self._deferred[i] + self._pending[i]
                self._deferred[i] = []

    # -- end of run ------------------------------------------------------------

    def final_release(self, now: float, queue: EventQueue) -> bool:
        """Release every still-queued frontier (trailing re-flood hops the
        synchronous engine charges in its next exchange or drain); returns
        whether anything was forwarded."""
        released = False
        for i in range(self.net.n):
            if self.net.topo.is_active(i) and self.net.states[i].frontier:
                self.release(i, now, queue)
                released = True
        return released

    def final_flush(self, final_step: int) -> FloodInbox | None:
        """Merge all deferred catch-up and drain every pending inbox — the
        event run always ends fully drained (every delivered message applied)."""
        self.merge_deferred(list(range(self.net.n)))
        return self.pop_inbox(list(range(self.net.n)), final_step)


class AsyncGossipTransport(TransportBase):
    """Barrier-mixing adapter: gossip averaging needs every client's step-t
    model, so mixes stay synchronization points; between mixes clients run
    free at their trace rates."""

    kind = "gossip"

    def __init__(self, inner: GossipTransport, trace: TraceSet,
                 extra_latency_s: float = 0.0):
        self.inner = inner
        self.trace = trace
        self.extra_latency_s = extra_latency_s
        self.every = inner.every

    @property
    def ledger(self):
        return self.inner.ledger

    def bind(self, init_payload) -> None:
        self.inner.bind(init_payload)

    def active_mask(self) -> np.ndarray:
        return self.inner.active_mask()

    def stats(self) -> dict:
        return self.inner.stats()

    def mix(self, payload, t: int, active: np.ndarray):
        """One mixing round through the wrapped transport; returns the mixed
        pytree and the virtual mix delay derived from the bytes it charged:

            2 * max latency + extra + per_edge_bytes * 8 / min bandwidth
        """
        before = self.inner.ledger.total_bytes
        mixed = self.inner.exchange(payload, t, active)
        sent = self.inner.ledger.total_bytes - before
        per_edge = sent / max(self.inner.live_edges, 1)
        bw = min(self.trace.bandwidth_bps)
        ser = 0.0 if bw == float("inf") else per_edge * 8.0 / bw
        delay = 2.0 * max(self.trace.latency_s) + self.extra_latency_s + ser
        return mixed, delay


def wrap_async(transport, trace: TraceSet, extra_latency_s: float = 0.0):
    """Wrap a synchronous Transport in its async adapter (the EventTrainer's
    transport argument)."""
    if isinstance(transport, FloodTransport):
        return AsyncFloodTransport(transport, trace, extra_latency_s)
    if isinstance(transport, GossipTransport):
        return AsyncGossipTransport(transport, trace, extra_latency_s)
    raise ValueError(f"{type(transport).__name__} has no async adapter "
                     "(event-driven runs support the flood and gossip "
                     "substrates)")
