import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers + compiles.

MUST be the process entry point (the XLA_FLAGS line above runs before any
jax import, including transitively through repro).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen2-72b --shape train_4k [--multipod] [--kind train] \
        [--out out.json] [--hlo-out out.hlo]

Emits a JSON record: memory_analysis, cost_analysis flops/bytes, parsed
collective stats, roofline terms — consumed by benchmarks/ and
EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse
import json
import sys
import time

import jax

from repro.configs import archs
from repro.configs.base import INPUT_SHAPES
from repro.launch import steps as steplib
from repro.launch.mesh import make_production_mesh, mesh_size
from repro.models import transformer as tf
from repro.roofline import analysis as ra
from repro.roofline import cost_model


def active_params(cfg) -> int:
    """Approximate activated parameter count (MoE: top-k+shared experts)."""
    import dataclasses
    from repro.configs.base import Group, MoECfg
    total = 0
    from repro.models import params as plib
    spec = tf.arch_spec(cfg)
    flat = plib.flatten_paths(spec)
    import math
    for path, leaf in flat.items():
        n = math.prod(leaf.shape)
        # expert-stacked leaves: scale by active fraction
        if "experts" in leaf.axes[: leaf.n_batch_dims]:
            e_dim = leaf.shape[leaf.axes.index("experts")]
            # find the owning MoE cfg: use top_k from any moe slot
            top_k = 8
            for g in cfg.groups:
                for s in g.slots:
                    if s.moe is not None:
                        top_k = s.moe.top_k
            n = n * top_k // e_dim
        total += n
    return total


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               kind: str | None = None, pod_kwargs: dict | None = None,
               save_hlo: str | None = None, verbose: bool = True,
               policy: str | None = None) -> dict:
    import dataclasses
    shape = INPUT_SHAPES[shape_name]
    base_cfg = archs.get(arch)
    cfg = base_cfg.for_shape(shape)
    if policy:
        cfg = dataclasses.replace(cfg, sharding_policy=policy)
    if pod_kwargs and pod_kwargs.pop("moe_gather", False):
        cfg = dataclasses.replace(cfg, moe_gather_weights=True)
    if pod_kwargs and pod_kwargs.pop("residual_rep", False):
        cfg = dataclasses.replace(cfg, residual_replicated=True)
    if kind is None:
        kind = {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]

    mesh = make_production_mesh(multi_pod=multi_pod)
    pod = steplib.PodConfig(**(pod_kwargs or {}))
    fn, example, in_sh, out_sh = steplib.build_step(kind, cfg, shape, mesh, pod)

    # exact per-device residency from the shardings (CPU memory_analysis is
    # not a per-chip proxy): params + inputs/caches, the ZO method's entire
    # live state — there are no grads or optimizer moments.
    def _per_device(abs_tree, sh_tree):
        import numpy as np
        total = 0.0
        for leaf, sh in zip(jax.tree.leaves(abs_tree), jax.tree.leaves(sh_tree)):
            nbytes = float(np.prod(leaf.shape)) * leaf.dtype.itemsize
            shards = 1
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for ax in jax.tree.leaves(tuple(sh.spec)):
                shards *= sizes.get(ax, 1)
            total += nbytes / shards
        return total

    resident = sum(_per_device(a, s) for a, s in zip(example, in_sh))

    t0 = time.time()   # lower/compile timing report only; never seeds anything
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*example)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = getattr(ma, k, None)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    cost = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        cost = {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        cost["error"] = str(e)

    hlo = compiled.as_text()
    coll = ra.parse_collectives_corrected(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    chips = mesh_size(mesh)
    # compute/memory numerators from the analytic model (cost_analysis counts
    # while bodies once — see roofline/cost_model.py); collectives from the
    # trip-count-corrected HLO parse.  coll.total_bytes is per-device link
    # traffic; × chips = network-total, as the roofline formula expects.
    mc = cost_model.step_cost(cfg, shape, kind,
                              rank=pod.rank,
                              n_clients=pod.n_clients or 16)
    flops, bytes_acc = mc.flops, mc.bytes
    n_active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq if kind in ("train", "prefill") else 1)
    mf = ra.model_flops_estimate(n_active, tokens, kind,
                                 zo=kind == "train")
    roof = ra.roofline_terms(flops, bytes_acc, coll.total_bytes * chips,
                             chips, mf)

    record = {
        "arch": arch, "effective_arch": cfg.name, "shape": shape_name,
        "kind": kind, "multi_pod": multi_pod, "chips": chips,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "policy": cfg.sharding_policy,
        "n_params": tf.count_params(cfg), "n_params_active": n_active,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem, "cost_analysis": cost,
        "resident_bytes_per_device": resident,
        "collectives": coll.to_json(), "roofline": roof.to_json(),
        "tokens": tokens,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {'2x16x16' if multi_pod else '16x16'} "
              f"kind={kind} OK lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  params={record['n_params']/1e9:.2f}B resident/dev="
              f"{resident/2**30:.2f}GiB flops={flops:.3e} bytes={bytes_acc:.3e} "
              f"coll={coll.total_bytes:.3e}B ({coll.count} ops)")
        print(f"  roofline: compute={ra.fmt_seconds(roof.compute_s)} "
              f"memory={ra.fmt_seconds(roof.memory_s)} "
              f"collective={ra.fmt_seconds(roof.collective_s)} "
              f"dominant={roof.dominant} useful={roof.useful_ratio:.2f}")
    return record


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=sorted(archs.REGISTRY))
    p.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    p.add_argument("--multipod", action="store_true")
    p.add_argument("--kind", default=None,
                   choices=[None, "train", "train_dsgd", "prefill", "decode"])
    p.add_argument("--out", default=None, help="write JSON record here")
    p.add_argument("--hlo-out", default=None)
    p.add_argument("--apply-mode", default="fold", choices=["fold", "buffer"])
    p.add_argument("--rank", type=int, default=32)
    p.add_argument("--n-clients", type=int, default=0)
    p.add_argument("--policy", default=None,
                   help="override the arch's sharding policy (tp/fsdp_tp/ep)")
    p.add_argument("--moe-gather", action="store_true",
                   help="all-gather expert weights at use (§Perf)")
    p.add_argument("--residual-rep", action="store_true",
                   help="pin residual stream d_model axis replicated (§Perf)")
    args = p.parse_args(argv)

    record = run_dryrun(args.arch, args.shape, multi_pod=args.multipod,
                        kind=args.kind, save_hlo=args.hlo_out,
                        policy=args.policy,
                        pod_kwargs={"apply_mode": args.apply_mode,
                                    "rank": args.rank,
                                    "n_clients": args.n_clients,
                                    "moe_gather": args.moe_gather,
                                    "residual_rep": args.residual_rep})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
