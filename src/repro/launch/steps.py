"""Sharded step programs for the production mesh.

``seedflood_train_step``  — the paper's Algorithm 1 mapped onto a pod:
  (A) subspace regenerated from (global_seed, τ⌊t/τ⌋) — identical on every
      shard, no communication;
  (B) per-client ZO estimation vmapped over the client axis (clients' batches
      shard over ("pod","data"); each client's forward differs from the
      shared θ only by its fused rank-1 SubCGE perturbation);
  (C) the flood: the per-client scalars α and coords are all-gathered by XLA
      (O(n·L) bytes — the whole point), the r×r coefficient scatters and the
      U A V^T weight update run identically on every shard.

``dsgd_train_step``       — the gossip baseline on the mesh: FO local step +
  ring collective_permute neighbour averaging (O(d) bytes — the contrast the
  roofline tables quantify).

``prefill_step`` / ``decode_step`` — the serving programs for the
inference-shaped inputs.

All builders return (fn, example_inputs, in_shardings, out_shardings) ready
for jax.jit(...).lower(...).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core import seeds as seedlib, subcge
from repro.core.subcge import SubCGEConfig
from repro.launch import mesh as meshlib
from repro.models import params as plib
from repro.models import transformer as tf
from repro.models.perturb import nest_subspace, sample_pert


@dataclasses.dataclass(frozen=True)
class PodConfig:
    lr: float = 1e-5
    eps: float = 1e-3
    rank: int = 32
    tau: int = 1000
    base_seed: int = 0
    param_dtype: Any = jnp.bfloat16
    n_clients: int = 0             # 0 -> data-axis extent of the mesh
    apply_mode: str = "fold"       # fold (UAV^T folded into W) | buffer
    remat_clients: bool = False    # lax.map over clients instead of vmap
    spmd_client_axis: bool = False  # bind the vmapped client axis to the
    #                                 data mesh axes (vmap spmd_axis_name)
    kernel_backend: str = "auto"   # SubCGE hot-path implementation: on a
    #                                real pod "auto" means the Pallas kernels
    #                                (repro.kernels.ops; DESIGN.md §7)

    def subcge(self) -> SubCGEConfig:
        return SubCGEConfig(rank=self.rank, refresh_period=self.tau,
                            kernel_backend=self.kernel_backend)


def _rep(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def train_inputs(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                 pod: PodConfig):
    """ShapeDtypeStructs + shardings for one training step."""
    n = pod.n_clients or meshlib.data_extent(mesh)
    assert shape.global_batch % n == 0, (shape.global_batch, n)
    b = shape.global_batch // n
    daxes = meshlib.data_axes(mesh)
    tspec = P(daxes, *([None] * 2))

    text = shape.seq - (cfg.frontend.n_embeds if cfg.frontend else 0)
    batch = {"tokens": jax.ShapeDtypeStruct((n, b, text), jnp.int32)}
    shard = {"tokens": NamedSharding(mesh, tspec)}
    if cfg.frontend is not None:
        fe = cfg.frontend
        batch["embeds"] = jax.ShapeDtypeStruct((n, b, fe.n_embeds, fe.embed_dim),
                                               pod.param_dtype)
        shard["embeds"] = NamedSharding(mesh, P(daxes, None, None, None))
    return batch, shard


def serve_batch_inputs(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                       pod: PodConfig, seq: int):
    B = shape.global_batch
    daxes = meshlib.data_axes(mesh)
    dsize = meshlib.data_extent(mesh)
    bspec = daxes if B % dsize == 0 else None
    text = seq - (cfg.frontend.n_embeds if cfg.frontend and seq > 1 else 0)
    batch = {"tokens": jax.ShapeDtypeStruct((B, text), jnp.int32)}
    shard = {"tokens": NamedSharding(mesh, P(bspec, None))}
    if cfg.frontend is not None and seq > 1:
        fe = cfg.frontend
        batch["embeds"] = jax.ShapeDtypeStruct((B, fe.n_embeds, fe.embed_dim),
                                               pod.param_dtype)
        shard["embeds"] = NamedSharding(mesh, P(bspec, None, None))
    return batch, shard


def cache_shardings(cfg: ArchConfig, cache_abs: Any, mesh: Mesh,
                    batch_sharded: bool) -> Any:
    """Shardings for the stacked cache tree.  Batch over data axes when it
    divides; otherwise (long_500k, B=1) the *sequence* axis shards over data.
    Head/feature axes shard over "model" when divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = meshlib.data_axes(mesh)
    dsize = meshlib.data_extent(mesh)

    def one(path: str, leaf):
        dims = [None] * len(leaf.shape)
        # leading dim is always the scan "reps" axis
        if path.endswith("kpos"):
            return NamedSharding(mesh, P(*dims))
        B = leaf.shape[1]
        if batch_sharded and B % dsize == 0:
            dims[1] = daxes
            seq_ok = False
        else:
            seq_ok = True
        name = path.split("/")[-1]
        if name in ("k", "v"):               # (reps, B, C, KV, hd)
            if seq_ok and leaf.shape[2] % dsize == 0:
                dims[2] = daxes
            if leaf.shape[3] % sizes.get("model", 1) == 0:
                dims[3] = "model"
            elif leaf.shape[4] % sizes.get("model", 1) == 0:
                dims[4] = "model"
        elif name in ("ckv", "krope"):       # (reps, B, C, dim)
            if seq_ok and leaf.shape[2] % dsize == 0:
                dims[2] = daxes
            # MLA compressed-feature dim over "model": without this the
            # 60L×32k×576 cache replicates across the model axis and a
            # 236B decode blows the 16 GB HBM budget (observed 18.9 GiB/dev)
            if leaf.shape[3] % sizes.get("model", 1) == 0:
                dims[3] = "model"
        elif name == "h":                    # (reps, B, Di, N)
            if leaf.shape[2] % sizes.get("model", 1) == 0:
                dims[2] = "model"
        elif name == "conv":                 # (reps, B, Kc-1, Di)
            if leaf.shape[3] % sizes.get("model", 1) == 0:
                dims[3] = "model"
        return NamedSharding(mesh, P(*dims))

    return seedlib.map_with_paths(one, cache_abs)


# ---------------------------------------------------------------------------
# SeedFlood train step
# ---------------------------------------------------------------------------

def build_seedflood_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                               pod: PodConfig):
    spec = tf.arch_spec(cfg)
    meta = plib.subcge_meta(spec)
    scfg = pod.subcge()
    n = pod.n_clients or meshlib.data_extent(mesh)

    params_abs = plib.abstract_params(spec, pod.param_dtype)
    params_sh = plib.tree_shardings(spec, mesh, cfg.sharding_policy)
    batch_abs, batch_sh = train_inputs(cfg, shape, mesh, pod)

    def train_step(params, batch, step):
        # buffer mode (paper App. A): params = (base W, A-buffers); the
        # effective weights W + U A V^T are materialized on the fly each
        # step and A is folded into W at subspace-refresh boundaries (a
        # buffer is only valid under the subspace it accumulated against).
        buffer_mode = pod.apply_mode == "buffer"
        if buffer_mode:
            params, bufs = params
            is_refresh = jnp.logical_and(step > 0,
                                         step % scfg.refresh_period == 0)
            old_sub = subcge.subspace_at_step(meta, scfg, pod.base_seed,
                                              jnp.maximum(step - 1, 0))
            params = jax.tree.map(
                lambda base, folded: jnp.where(is_refresh, folded, base),
                params, subcge.fold_buffers(params, meta, old_sub, bufs,
                                            backend=pod.kernel_backend))
            bufs = jax.tree.map(
                lambda b: jnp.where(is_refresh, jnp.zeros_like(b), b), bufs)

        sub_flat = subcge.subspace_at_step(meta, scfg, pod.base_seed, step)
        sub = nest_subspace(sub_flat)
        eff = (subcge.effective_params(params, meta, sub_flat, bufs,
                                       backend=pod.kernel_backend)
               if buffer_mode else params)
        cids = jnp.arange(n)
        seeds_t = jax.vmap(lambda i: seedlib.client_seed(pod.base_seed, step, i))(cids)

        def client_alpha(batch_i, seed_i):
            pert = sample_pert(meta, scfg, seed_i, pod.eps)
            lp = tf.lm_loss(cfg, eff, batch_i, sub=sub, pert=pert,
                            kernel_backend=pod.kernel_backend)
            lm = tf.lm_loss(cfg, eff, batch_i, sub=sub,
                            pert=pert.with_scale(-pod.eps),
                            kernel_backend=pod.kernel_backend)
            return (lp - lm) / (2 * pod.eps), 0.5 * (lp + lm)

        if pod.remat_clients:
            alphas, losses = jax.lax.map(lambda ab: client_alpha(ab[0], ab[1]),
                                         (batch, seeds_t))
        elif pod.spmd_client_axis:
            daxes = meshlib.data_axes(mesh)
            alphas, losses = jax.vmap(
                client_alpha,
                spmd_axis_name=daxes if len(daxes) > 1 else daxes[0],
            )(batch, seeds_t)
        else:
            alphas, losses = jax.vmap(client_alpha)(batch, seeds_t)

        # --- consensus: the flood-equivalent all-gather of (seed, α) -------
        coefs = (-pod.lr / n) * alphas
        metrics = {"loss": jnp.mean(losses),
                   "alpha_rms": jnp.sqrt(jnp.mean(alphas ** 2)),
                   "step": step}
        if buffer_mode:  # O(n) coordinate updates only (Table 4 "MA" row);
            # non-matrix leaves follow MeZO directly (App. A)
            bufs = subcge.accumulate_buffers(bufs, meta, scfg, seeds_t, coefs)
            params = subcge.apply_vector_messages(params, meta, scfg,
                                                  seeds_t, coefs)
            return (params, bufs), metrics
        new_params = subcge.apply_messages(params, meta, scfg, sub_flat,
                                           seeds_t, coefs)
        return new_params, metrics

    if pod.apply_mode == "buffer":
        bufs_abs = jax.eval_shape(lambda: subcge.zero_buffers(meta, scfg))
        bufs_sh = seedlib.map_with_paths(lambda p, l: _rep(mesh), bufs_abs)
        example = ((params_abs, bufs_abs), batch_abs,
                   jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = ((params_sh, bufs_sh), batch_sh, _rep(mesh))
        out_sh = ((params_sh, bufs_sh), _rep(mesh))
    else:
        example = (params_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (params_sh, batch_sh, _rep(mesh))
        out_sh = (params_sh, _rep(mesh))
    return train_step, example, in_sh, out_sh


# ---------------------------------------------------------------------------
# DSGD gossip baseline on the mesh (roofline contrast)
# ---------------------------------------------------------------------------

def build_dsgd_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                          pod: PodConfig):
    """FO local step + one ring-gossip round via ppermute over the client
    axis.  Parameters are replicated per client group along "data"; the
    gossip traffic is the full parameter pytree — O(d) per edge, the cost
    Table 1 contrasts with SeedFlood's O(n)."""
    spec = tf.arch_spec(cfg)
    params_abs = plib.abstract_params(spec, pod.param_dtype)
    params_sh = plib.tree_shardings(spec, mesh, cfg.sharding_policy)
    batch_abs, batch_sh = train_inputs(cfg, shape, mesh, pod)

    def train_step(params, batch, step):
        # per-client gradient on the client's shard (vmapped like SeedFlood)
        def client_loss(p, b):
            return tf.lm_loss(cfg, p, b)

        def grad_i(batch_i):
            return jax.value_and_grad(lambda p: client_loss(p, batch_i))(params)

        losses, grads = jax.vmap(grad_i)(batch)
        # DSGD with uniform mixing after local steps ≈ allreduce of the
        # update followed by neighbour exchange; we lower the honest version:
        # average gradients (the consensus collective is O(d)·allreduce).
        gbar = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        new_params = jax.tree.map(lambda p, g: p - pod.lr * g.astype(p.dtype),
                                  params, gbar)
        return new_params, {"loss": jnp.mean(losses), "step": step}

    example = (params_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (params_sh, batch_sh, _rep(mesh))
    out_sh = (params_sh, _rep(mesh))
    return train_step, example, in_sh, out_sh


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                       pod: PodConfig):
    spec = tf.arch_spec(cfg)
    params_abs = plib.abstract_params(spec, pod.param_dtype)
    params_sh = plib.tree_shardings(spec, mesh, cfg.sharding_policy)
    batch_abs, batch_sh = serve_batch_inputs(cfg, shape, mesh, pod, shape.seq)
    cache_abs = tf.abstract_cache(cfg, shape.global_batch, shape.seq,
                                  pod.param_dtype)
    dsize = meshlib.data_extent(mesh)
    cache_sh = cache_shardings(cfg, cache_abs, mesh,
                               batch_sharded=shape.global_batch % dsize == 0)

    def prefill_step(params, batch):
        cache = tf.init_cache(cfg, shape.global_batch, shape.seq,
                              pod.param_dtype)
        logits, new_cache, _ = tf.forward(cfg, params, batch, cache=cache,
                                          pos=jnp.int32(0))
        # return only the last-position logits (sampling input) + cache
        return logits[:, -1], new_cache

    example = (params_abs, batch_abs)
    in_sh = (params_sh, batch_sh)
    out_sh = (_rep(mesh), cache_sh)
    return prefill_step, example, in_sh, out_sh


def build_decode_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                      pod: PodConfig):
    """One new token against a KV cache of ``shape.seq``.

    moe_gather_weights is force-disabled here: at decode the activation
    buffers are tiny (B×1 tokens), so psumming them costs ~nothing while
    gathering TBs of expert weights per step regressed kimi decode 4.6×
    (measured — see EXPERIMENTS.md §Perf sweep).
    """
    cfg = dataclasses.replace(cfg, moe_gather_weights=False)
    spec = tf.arch_spec(cfg)
    params_abs = plib.abstract_params(spec, pod.param_dtype)
    params_sh = plib.tree_shardings(spec, mesh, cfg.sharding_policy)
    B = shape.global_batch
    cache_abs = tf.abstract_cache(cfg, B, shape.seq, pod.param_dtype)
    dsize = meshlib.data_extent(mesh)
    batch_sharded = B % dsize == 0
    cache_sh = cache_shardings(cfg, cache_abs, mesh, batch_sharded=batch_sharded)
    daxes = meshlib.data_axes(mesh)
    tok_sh = NamedSharding(mesh, P(daxes if batch_sharded else None, None))

    def decode_step(params, cache, tokens, pos):
        logits, new_cache, _ = tf.forward(cfg, params, {"tokens": tokens},
                                          cache=cache, pos=pos)
        return logits[:, 0], new_cache

    example = (params_abs, cache_abs,
               jax.ShapeDtypeStruct((B, 1), jnp.int32),
               jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (params_sh, cache_sh, tok_sh, _rep(mesh))
    out_sh = (_rep(mesh), cache_sh)
    return decode_step, example, in_sh, out_sh


# ---------------------------------------------------------------------------
# paged serving steps (repro.serve; DESIGN.md §10)
# ---------------------------------------------------------------------------

def paged_pool_shardings(cfg: ArchConfig, pool_abs: Any, mesh: Mesh) -> Any:
    """Shardings for the paged KV pool tree (reps, P, page, KV, hd): head /
    feature axes shard over "model" when divisible; page axes stay whole —
    the pool is indexed by physical page id, which must not be split."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path: str, leaf):
        dims = [None] * len(leaf.shape)
        if leaf.shape[3] % sizes.get("model", 1) == 0:
            dims[3] = "model"
        elif leaf.shape[4] % sizes.get("model", 1) == 0:
            dims[4] = "model"
        return NamedSharding(mesh, P(*dims))

    return seedlib.map_with_paths(one, pool_abs)


def _paged_geometry(shape: InputShape, page_size: int | None,
                    pages_per_req: int | None, n_pages: int | None):
    """Default paged-pool geometry for a (seq, batch) serving shape."""
    if page_size is None:
        page_size = min(16, shape.seq)
    if pages_per_req is None:
        pages_per_req = -(-shape.seq // page_size)
    if n_pages is None:
        n_pages = shape.global_batch * pages_per_req
    return page_size, pages_per_req, n_pages


def build_paged_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                             pod: PodConfig, *, page_size: int | None = None,
                             pages_per_req: int | None = None,
                             n_pages: int | None = None):
    """Prefill ``global_batch`` same-length prompts and scatter their KV into
    the pool rows given by ``table``.  The prefill forward runs against a
    throwaway monolithic cache of capacity == prompt length (prefill logits
    are cache-layout independent: the T > 1 path attends the raw k/v), so
    the returned last-position logits are bitwise the monolithic prefill's.
    """
    tf.check_paged_support(cfg)
    page_size, pages_per_req, n_pages = _paged_geometry(
        shape, page_size, pages_per_req, n_pages)
    spec = tf.arch_spec(cfg)
    params_abs = plib.abstract_params(spec, pod.param_dtype)
    params_sh = plib.tree_shardings(spec, mesh, cfg.sharding_policy)
    Bg, T = shape.global_batch, shape.seq
    pool_abs = tf.abstract_paged_pool(cfg, n_pages, page_size, pod.param_dtype)
    pool_sh = paged_pool_shardings(cfg, pool_abs, mesh)

    def prefill_step(params, pool, tokens, table):
        cache = tf.init_cache(cfg, Bg, T, pod.param_dtype)
        logits, cache, _ = tf.forward(cfg, params, {"tokens": tokens},
                                      cache=cache, pos=jnp.int32(0))
        pool = tf.write_prefill_to_pages(cfg, cache, pool, table, page_size)
        return logits[:, -1], pool

    example = (params_abs, pool_abs,
               jax.ShapeDtypeStruct((Bg, T), jnp.int32),
               jax.ShapeDtypeStruct((Bg, pages_per_req), jnp.int32))
    in_sh = (params_sh, pool_sh, _rep(mesh), _rep(mesh))
    out_sh = (_rep(mesh), pool_sh)
    return prefill_step, example, in_sh, out_sh


def build_paged_decode_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                            pod: PodConfig, *, page_size: int | None = None,
                            pages_per_req: int | None = None,
                            n_pages: int | None = None):
    """One token for ``global_batch`` continuous-batching request slots
    against the paged KV pool.  Unlike :func:`build_decode_step`, ``pos`` is
    a per-request (B,) vector and the attended width is the (bucketed) table
    width ``pages_per_req``·``page_size``, not a monolithic capacity — the
    serve scheduler compiles one trace per page bucket and re-dispatches as
    the longest active request grows.

    moe_gather_weights is force-disabled for the same reason as the
    monolithic decode step (see :func:`build_decode_step`).
    """
    cfg = dataclasses.replace(cfg, moe_gather_weights=False)
    tf.check_paged_support(cfg)
    page_size, pages_per_req, n_pages = _paged_geometry(
        shape, page_size, pages_per_req, n_pages)
    spec = tf.arch_spec(cfg)
    params_abs = plib.abstract_params(spec, pod.param_dtype)
    params_sh = plib.tree_shardings(spec, mesh, cfg.sharding_policy)
    B = shape.global_batch
    pool_abs = tf.abstract_paged_pool(cfg, n_pages, page_size, pod.param_dtype)
    pool_sh = paged_pool_shardings(cfg, pool_abs, mesh)

    def decode_step(params, pool, tokens, table, pos_b):
        logits, new_pool, _ = tf.forward(cfg, params, {"tokens": tokens},
                                         cache=pool, pos=pos_b,
                                         paged_table=table)
        return logits[:, 0], new_pool

    example = (params_abs, pool_abs,
               jax.ShapeDtypeStruct((B, 1), jnp.int32),
               jax.ShapeDtypeStruct((B, pages_per_req), jnp.int32),
               jax.ShapeDtypeStruct((B,), jnp.int32))
    in_sh = (params_sh, pool_sh, _rep(mesh), _rep(mesh), _rep(mesh))
    out_sh = (_rep(mesh), pool_sh)
    return decode_step, example, in_sh, out_sh


BUILDERS = {
    "train": build_seedflood_train_step,
    "train_dsgd": build_dsgd_train_step,
    "prefill": build_prefill_step,
    "decode": build_decode_step,
    "prefill_paged": build_paged_prefill_step,
    "decode_paged": build_paged_decode_step,
}


def build_step(kind: str, cfg: ArchConfig, shape: InputShape, mesh: Mesh,
               pod: PodConfig):
    return BUILDERS[kind](cfg, shape, mesh, pod)
