"""Pod serving driver: continuous-batching decode over a paged KV cache
(repro.serve).  Requests admit and evict per step, prefill scatters into
reserved pages, and decode runs one bucketed dispatch per step — the same
programs the serve swarm simulator drives under churn.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch tinyllama-1.1b --reduced --batch 4 --prompt-len 32 --new 16 \
        --sampling greedy

``--sampling temperature --temperature 0.8`` switches to temperature
sampling (keyed per (request, position), so a run is deterministic).  On a
real pod drop --reduced and add --production-mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import params as plib
from repro.models import transformer as tf
from repro.serve import SAMPLING_KINDS, DecodeServer, Request, ServeConfig


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b",
                   choices=sorted(archs.REGISTRY))
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--requests", type=int, default=None,
                   help="total requests to serve (default: --batch)")
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new", type=int, default=16)
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--production-mesh", action="store_true")
    p.add_argument("--sampling", choices=SAMPLING_KINDS, default="greedy")
    p.add_argument("--temperature", type=float, default=0.8)
    args = p.parse_args(argv)

    cfg = archs.get(args.arch)
    if args.reduced:
        cfg = archs.reduced(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(1, len(jax.devices())))
    pod = steplib.PodConfig(param_dtype=jnp.float32 if args.reduced
                            else jnp.bfloat16)

    n_req = args.requests if args.requests is not None else args.batch
    page = min(args.page_size, args.prompt_len + args.new)
    ppr = -(-(args.prompt_len + args.new) // page)
    serve = ServeConfig(max_batch=args.batch, page_size=page,
                        n_pages=args.batch * ppr, max_seq=ppr * page,
                        sampling=args.sampling,
                        temperature=args.temperature,
                        param_dtype=pod.param_dtype)

    params = plib.init_params(tf.arch_spec(cfg), 0, pod.param_dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(0),
                                 (n_req, args.prompt_len), 0, cfg.vocab)

    srv = DecodeServer(cfg, params, serve, mesh=mesh, pod=pod)
    for b in range(n_req):
        srv.submit(Request(rid=b, prompt=np.asarray(prompts[b], np.int32),
                           max_new=args.new))
    t0 = time.perf_counter()
    results = srv.run()
    dt = time.perf_counter() - t0

    emitted = sum(len(v) for v in results.values())
    print(f"{cfg.name}: {n_req} requests x {args.new} new tokens "
          f"({args.sampling}); {emitted / dt:.1f} tok/s; "
          f"stats={srv.stats()}")
    for b in range(n_req):
        print(f"  req{b}: {results[b]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
