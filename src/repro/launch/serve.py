"""Pod serving driver: prefill a batch of requests, then decode tokens with
the production decode_step (the program the decode_32k / long_500k dry-runs
lower at 256/512-chip scale).

    PYTHONPATH=src python -m repro.launch.serve \
        --arch tinyllama-1.1b --reduced --batch 4 --prompt-len 32 --new 16

On a real pod drop --reduced and add --production-mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.base import InputShape
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import params as plib
from repro.models import transformer as tf


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b",
                   choices=sorted(archs.REGISTRY))
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new", type=int, default=16)
    p.add_argument("--production-mesh", action="store_true")
    p.add_argument("--greedy", action="store_true", default=True)
    args = p.parse_args(argv)

    cfg = archs.get(args.arch)
    if args.reduced:
        cfg = archs.reduced(cfg)
    capacity = args.prompt_len + args.new
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(1, len(jax.devices())))
    pod = steplib.PodConfig(param_dtype=jnp.float32 if args.reduced
                            else jnp.bfloat16)

    dshape = InputShape("serve", capacity, args.batch, "decode")
    decode, _, in_sh, out_sh = steplib.build_decode_step(cfg, dshape, mesh, pod)

    params = plib.init_params(tf.arch_spec(cfg), 0, pod.param_dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(0),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    with mesh:
        cache = tf.init_cache(cfg, args.batch, capacity, pod.param_dtype)
        logits, cache, _ = tf.forward(cfg, params, {"tokens": prompts},
                                      cache=cache, pos=0)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        decode_j = jax.jit(decode, in_shardings=in_sh, out_shardings=out_sh)
        out = [tok]
        t0 = time.perf_counter()
        for i in range(args.new - 1):
            lg, cache = decode_j(params, cache, tok,
                                 jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(lg, axis=-1)[:, None]
            out.append(tok)
        dt = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"{cfg.name}: {args.batch} requests, {args.new} new tokens each; "
          f"{args.batch * (args.new - 1) / dt:.1f} tok/s")
    for b in range(args.batch):
        print(f"  req{b}: {gen[b].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
