"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: 16×16 = 256 chips ("data", "model").
Multi-pod: 2×16×16 = 512 chips ("pod", "data", "model") — the "pod" axis is
the slow inter-pod (DCN) dimension; SeedFlood's client axis spans
("pod", "data"), which is exactly the regime the paper targets: the
cross-pod traffic is seed-scalar messages, not tensors.

Hardware constants (TPU v5e-class, per chip) used by the roofline analysis.
"""
from __future__ import annotations

import jax
import numpy as np


# roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def mesh_size(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the client/batch dimension spans."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_extent(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in data_axes(mesh):
        out *= sizes[a]
    return out
