"""Batch dry-run driver: every (arch × shape) on the 16×16 mesh + the
2×16×16 multi-pod mesh.  Each run is an isolated subprocess (fresh XLA
device-count env; one failure never kills the batch).  Results land in
results/dryrun/<arch>__<shape>__<mesh>.json and are summarized by
benchmarks/roofline_table.py.

    PYTHONPATH=src python -m repro.launch.dryrun_all [--only-single] \
        [--archs a,b] [--shapes s1,s2] [--skip-existing]
"""
import argparse
import json
import os
import subprocess
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")

ARCHS = [
    "jamba-1.5-large-398b", "qwen1.5-0.5b", "tinyllama-1.1b", "qwen2-72b",
    "kimi-k2-1t-a32b", "musicgen-medium", "internvl2-26b", "falcon-mamba-7b",
    "gemma3-1b", "deepseek-v2-236b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_one(arch: str, shape: str, multipod: bool, out_path: str,
            timeout: int = 1800, extra=()) -> dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out_path, *extra]
    if multipod:
        cmd.append("--multipod")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    t0 = time.time()   # wall_s report field only; never seeds anything
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        ok = p.returncode == 0
        err = ("" if ok else (p.stderr or p.stdout)[-3000:])
    except subprocess.TimeoutExpired:
        ok, err = False, f"timeout after {timeout}s"
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multipod else "16x16",
           "ok": ok, "wall_s": round(time.time() - t0, 1)}
    if not ok:
        rec["error"] = err
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--only-single", action="store_true")
    p.add_argument("--only-multi", action="store_true")
    p.add_argument("--archs", default=",".join(ARCHS))
    p.add_argument("--shapes", default=",".join(SHAPES))
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--results", default=RESULTS)
    args = p.parse_args(argv)

    os.makedirs(args.results, exist_ok=True)
    meshes = [False, True]
    if args.only_single:
        meshes = [False]
    if args.only_multi:
        meshes = [True]

    status = []
    for multipod in meshes:
        for arch in args.archs.split(","):
            for shape in args.shapes.split(","):
                tag = f"{arch}__{shape}__{'2x16x16' if multipod else '16x16'}"
                out = os.path.join(args.results, tag + ".json")
                if args.skip_existing and os.path.exists(out):
                    try:
                        ok = "error" not in json.load(open(out))
                    except Exception:
                        ok = False
                    if ok:
                        print(f"[skip] {tag}")
                        continue
                rec = run_one(arch, shape, multipod, out)
                status.append(rec)
                flag = "OK " if rec["ok"] else "FAIL"
                print(f"[{flag}] {tag} ({rec['wall_s']}s)"
                      + ("" if rec["ok"] else f"\n  {rec.get('error','')[:500]}"),
                      flush=True)

    n_fail = sum(not r["ok"] for r in status)
    print(f"\n{len(status) - n_fail}/{len(status)} dry-runs passed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
