"""Pod training driver: runs the sharded SeedFlood train_step in a loop.

On a real TPU pod this is the production entry point (one process per host;
jax.distributed.initialize() handles the rest).  On CPU it runs the same
program on a host mesh at reduced scale — the step function is identical to
the one the dry-runs lower for 256/512 chips.

    PYTHONPATH=src python -m repro.launch.train \
        --arch tinyllama-1.1b --reduced --steps 20 --batch 8 --seq 64

Checkpoints (params + step + seed — ZO has no optimizer state) land in
--ckpt-dir every --ckpt-every steps.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import archs
from repro.configs.base import InputShape
from repro.data import synthetic
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import params as plib
from repro.models import transformer as tf


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b",
                   choices=sorted(archs.REGISTRY))
    p.add_argument("--reduced", action="store_true",
                   help="reduced config (CPU-scale)")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8, help="global batch")
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--n-clients", type=int, default=2)
    p.add_argument("--lr", type=float, default=1e-2)
    p.add_argument("--rank", type=int, default=16)
    p.add_argument("--production-mesh", action="store_true",
                   help="use the 16x16 pod mesh (requires 256 devices)")
    p.add_argument("--ckpt-dir", default="/tmp/seedflood_pod")
    p.add_argument("--ckpt-every", type=int, default=0)
    args = p.parse_args(argv)

    cfg = archs.get(args.arch)
    if args.reduced:
        cfg = archs.reduced(cfg)
    shape = InputShape("cli", args.seq, args.batch, "train")

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(1, len(jax.devices())))
    pod = steplib.PodConfig(lr=args.lr, rank=args.rank,
                            n_clients=args.n_clients,
                            param_dtype=jnp.float32 if args.reduced
                            else jnp.bfloat16)
    fn, example, in_sh, out_sh = steplib.build_seedflood_train_step(
        cfg, shape, mesh, pod)

    # synthetic corpus, partitioned across the logical clients
    task = synthetic.TaskConfig(vocab=cfg.vocab, seq_len=args.seq - 1,
                                n_train=max(256, args.batch * 8))
    train, _, test = synthetic.make_splits(task)
    parts = synthetic.partition(train, args.n_clients)

    params = plib.init_params(tf.arch_spec(cfg), 0, pod.param_dtype)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        per_client = args.batch // args.n_clients
        # throughput timing only: data + perturbations key off (base_seed,
        # client, step) so a re-run is bit-identical — never clock-seed here
        t0 = time.time()
        for step in range(args.steps):
            toks = np.stack([
                np.asarray(synthetic.client_batch(train, parts[i], i, step,
                                                  per_client)["tokens"])
                for i in range(args.n_clients)])
            params, metrics = jitted(params, {"tokens": jnp.asarray(toks)},
                                     jnp.int32(step))
            if step % max(1, args.steps // 10) == 0:
                print(f"step {step:>5}  loss {float(metrics['loss']):.4f}  "
                      f"alpha_rms {float(metrics['alpha_rms']):.4f}", flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                path = os.path.join(args.ckpt_dir, f"step{step + 1}.npz")
                ckpt.save(path, params, {"step": step + 1, "arch": cfg.name})
                print(f"  saved {path}")
        dt = time.time() - t0

    acc = synthetic.accuracy(cfg, params, test, forward_fn=tf.forward)
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s); test accuracy {acc:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
