"""Decentralized training entry point — config, registry, and the `run()`
wrapper.

Implements Algorithm 1 (SeedFlood) over a real message-passing network,
plus every baseline of §4.2, with exact per-edge byte ledgers:

  seedflood     flooding of seed-scalar ZO messages + SubCGE aggregation
  dzsgd         ZO local steps + gossip model averaging (Tang et al., 2020)
  dsgd          FO local steps + gossip model averaging (Lian et al., 2017)
  choco         FO + compressed-difference gossip, 99% top-k (Koloskova 2019)
  dsgd_lora / dzsgd_lora / choco_lora   — adapters-only training+gossip
  gossip_sr     gossip with shared randomness (paper §3.2 strawman; O(tnd))
  central_zo    centralized n-perturbation ZO (equivalence oracle for tests)

The training loops themselves no longer live here.  Each method is a
``Method`` plugin (``repro.dtrain.methods``) composed with a ``Transport``
plugin (``repro.core.transport``) and driven by the single churn-aware
``Trainer`` (``repro.dtrain.trainer``) — see DESIGN.md §4 for the contract
and the composition table.  This module owns only:

* :class:`DTrainConfig` — the one config every method runs behind;
* :func:`validate_config` — per-method rejection of silently-ignored fields;
* :data:`METHODS` — the name -> runner-callable registry (back-compat);
* :func:`run` — build Setup/Method/Transport/Trainer and go.

Every method keeps *per-client* parameters stacked on a leading client axis
(SeedFlood clients provably coincide after full flooding — a test asserts
this rather than assuming it) and reports Global Model Performance of the
averaged model, the paper's GMP metric.  Runs can be subjected to **churn**
(DESIGN.md §6) and checkpointed/resumed bitwise (``checkpoint_every`` /
``resume_from``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import KERNEL_BACKENDS, ArchConfig, ChurnConfig
from repro.data import synthetic
from repro.dtrain.api import RunResult, Setup, sim_arch  # noqa: F401  (re-export)
from repro.dtrain.methods import METHOD_SPECS, MethodSpec
from repro.dtrain.trainer import Trainer
from repro.sim import EventTrainer, as_trace, wrap_async
from repro.topology.dynamic import ChurnSchedule


@dataclasses.dataclass
class DTrainConfig:
    method: str = "seedflood"
    n_clients: int = 8
    topology: str = "ring"
    steps: int = 200
    lr: float = 1e-2
    batch_size: int = 8
    eps: float = 1e-3
    local_iters: int = 5            # communicate every 5 local steps (paper)
    flood_k: int | None = None      # None -> network diameter (full flooding)
    subcge_rank: int = 16
    subcge_tau: int = 1000
    choco_density: float = 0.01     # 99% top-k sparsification (paper)
    lora_r: int = 8
    lora_alpha: float = 16.0
    momentum: float = 0.0           # beyond-paper: subspace momentum β
    eval_every: int = 0             # 0 = only at the end
    seed: int = 0
    partition: str = "uniform"
    arch: ArchConfig | None = None
    task: synthetic.TaskConfig | None = None
    # churn (DESIGN.md §6): a ChurnSchedule or declarative ChurnConfig; None
    # reproduces the paper's static-topology setting exactly.
    churn: Any = None
    # flood engine: "python" (per-message reference), "numpy" (bitset fast
    # path), or "auto" (numpy once n_clients is large enough to pay off).
    flood_backend: str = "auto"
    # True: the whole estimate -> local update -> replay pipeline runs as
    # jit-resident batched calls over the stacked client axis.  False: the
    # per-client reference path (2n tree-unstack/dispatch/restack cycles per
    # step) — kept for parity tests and the bench_step speedup baseline.
    batched_step: bool = True
    # True (the fix): replay every received message under its SENDER's
    # subspace epoch.  False pins the legacy receiver-step replay — wrong
    # whenever staleness crosses a τ boundary; exists only so regression
    # tests can demonstrate the bug.
    epoch_replay: bool = True
    # After the last training step, keep flooding + replaying (no new
    # injections) until the network is quiescent, so delayed-flooding runs
    # end with every message delivered (and, with epoch_replay, consensus).
    drain: bool = False
    # checkpointing: every k steps the Trainer snapshots method + transport
    # state to checkpoint_dir/stepNNNNNN.npz; resume_from restores one and
    # continues bitwise-identically.
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    resume_from: str = ""
    # which implementation the SubCGE hot paths (matrix-leaf replay + the
    # perturbed dual forward) run through: "auto" resolves once per process
    # (Pallas on TPU, the bitwise pure-jnp oracles elsewhere); "interpret"
    # drives the real Pallas kernels through the interpreter (CI on CPU).
    # See repro.kernels.ops and DESIGN.md §7.
    kernel_backend: str = "auto"
    # event-driven asynchronous runs (DESIGN.md §9): a TraceSet, trace-JSON
    # dict, or path to one switches the run onto the discrete-event
    # EventTrainer, where each client steps at its trace rate and flood
    # messages arrive with per-edge delay.  None keeps the synchronous
    # barrier loop (with TraceSet.constant defaults the two are bitwise
    # identical — pinned by tests/test_sim.py).
    trace: Any = None
    # extra per-delivery latency added on top of the trace's per-client
    # propagation terms (one knob for "same trace, slower network").
    sim_latency_s: float = 0.0
    # virtual seconds one churn-schedule step index spans; None uses the
    # trace's median per-step compute time.
    sim_churn_step_s: float | None = None


#: DTrainConfig fields that belong to specific methods.  A non-default value
#: for a field outside its method's ``consumes`` set is a config error, not
#: a silent no-op (the shared fields — steps, lr, topology, subcge_*, … —
#: are consumed by enough methods that rejecting them would be noise).
_METHOD_FIELDS = ("momentum", "choco_density", "flood_k", "flood_backend",
                  "batched_step", "epoch_replay", "drain", "lora_r",
                  "lora_alpha", "kernel_backend", "trace", "sim_latency_s",
                  "sim_churn_step_s")

_DEFAULTS = {f.name: f.default for f in dataclasses.fields(DTrainConfig)}


def validate_config(cfg: DTrainConfig, spec: MethodSpec | None = None) -> None:
    """Reject configs whose method-specific fields would be silently ignored.

    Raises ``KeyError`` for an unknown method and ``ValueError`` for a field
    the chosen method does not consume (e.g. ``momentum`` outside
    ``central_zo``, ``choco_density`` outside the choco variants,
    ``flood_k`` outside ``seedflood``) or for churn on a static-only method.
    """
    if spec is None:
        if cfg.method not in METHOD_SPECS:
            raise KeyError(f"unknown method '{cfg.method}' "
                           f"(have {sorted(METHOD_SPECS)})")
        spec = METHOD_SPECS[cfg.method]
    if cfg.kernel_backend not in KERNEL_BACKENDS:
        raise ValueError(f"kernel_backend must be one of {KERNEL_BACKENDS}, "
                         f"got {cfg.kernel_backend!r}")
    for field in _METHOD_FIELDS:
        if field in spec.consumes:
            continue
        if getattr(cfg, field) != _DEFAULTS[field]:
            users = sorted(name for name, s in METHOD_SPECS.items()
                           if field in s.consumes)
            raise ValueError(
                f"config field '{field}'={getattr(cfg, field)!r} is not "
                f"consumed by method '{spec.name}' and would be silently "
                f"ignored (only {users} read it)")
    if cfg.churn is not None and not spec.supports_churn:
        raise ValueError(f"method '{spec.name}' does not support churn")
    if cfg.trace is None:
        if cfg.sim_latency_s != 0.0 or cfg.sim_churn_step_s is not None:
            raise ValueError(
                "sim_latency_s/sim_churn_step_s only apply to event-driven "
                "runs and would be silently ignored — set 'trace' as well")
    else:
        if cfg.checkpoint_every or cfg.resume_from:
            raise ValueError("event-driven runs do not support "
                             "checkpoint/resume yet")
        if cfg.flood_k is not None:
            raise ValueError("flood_k has no meaning under per-edge "
                             "timestamped delivery — unset it for trace runs")
        if not cfg.epoch_replay:
            raise ValueError("event-driven runs require epoch_replay=True: "
                             "arbitrarily stale arrivals are only exact "
                             "under sender-epoch replay")
        if cfg.flood_backend == "numpy":
            raise ValueError("the numpy bitset flood engine is "
                             "round-synchronous; event-driven runs need "
                             "flood_backend='python' (or 'auto')")
        if cfg.drain:
            raise ValueError("event-driven runs always drain — "
                             "'drain' would be silently ignored")
        if cfg.churn is not None and spec.name != "seedflood":
            raise ValueError(f"method '{spec.name}' cannot combine churn "
                             "with a trace (gossip mixing is a barrier over "
                             "all clients)")
    if cfg.checkpoint_every and not cfg.checkpoint_dir:
        raise ValueError("checkpoint_every requires checkpoint_dir")
    if cfg.checkpoint_dir and not cfg.checkpoint_every:
        raise ValueError("checkpoint_dir is set but checkpoint_every is 0 — "
                         "no checkpoints would be written")


def _churn_schedule(cfg: DTrainConfig) -> ChurnSchedule | None:
    if cfg.churn is None:
        return None
    if isinstance(cfg.churn, ChurnSchedule):
        return cfg.churn
    if isinstance(cfg.churn, ChurnConfig):
        return ChurnSchedule.from_config(cfg.churn)
    raise TypeError(f"churn must be a ChurnSchedule or ChurnConfig, "
                    f"got {type(cfg.churn).__name__}")


def _run_spec(spec: MethodSpec, cfg: DTrainConfig) -> RunResult:
    validate_config(cfg, spec)
    if cfg.trace is not None:
        return _run_event(spec, cfg)
    setup = Setup(cfg)
    method = spec.make_method(cfg)
    transport = spec.make_transport(cfg, setup)
    return Trainer(cfg, setup, method, transport,
                   churn=_churn_schedule(cfg)).run()


def _run_event(spec: MethodSpec, cfg: DTrainConfig) -> RunResult:
    """Trace-clocked asynchronous run: same Method, async-adapted Transport,
    EventTrainer loop (DESIGN.md §9)."""
    trace = as_trace(cfg.trace, cfg.n_clients)
    if "flood_backend" in spec.consumes:
        # the event engine delivers per edge; only the per-message reference
        # engine supports that ("auto" would pick the bitset engine at scale)
        cfg = dataclasses.replace(cfg, flood_backend="python")
    setup = Setup(cfg)
    method = spec.make_method(cfg)
    transport = wrap_async(spec.make_transport(cfg, setup), trace,
                           cfg.sim_latency_s)
    return EventTrainer(cfg, setup, method, transport, trace,
                        churn=_churn_schedule(cfg)).run()


def _method_runner(spec: MethodSpec) -> Callable[[DTrainConfig], RunResult]:
    def runner(cfg: DTrainConfig) -> RunResult:
        return _run_spec(spec, cfg)
    runner.__name__ = f"run_{spec.name}"
    runner.__doc__ = f"Run '{spec.name}' via the Method × Transport Trainer."
    return runner


#: Back-compat registry: name -> callable(cfg) -> RunResult, exactly the
#: surface the pre-plugin monolith exported.
METHODS: dict[str, Callable[[DTrainConfig], RunResult]] = {
    name: _method_runner(spec) for name, spec in METHOD_SPECS.items()}


def run(cfg: DTrainConfig) -> RunResult:
    if cfg.method not in METHODS:
        raise KeyError(f"unknown method '{cfg.method}' (have {sorted(METHODS)})")
    return METHODS[cfg.method](cfg)
