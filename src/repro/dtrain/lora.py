"""LoRA adapters for the communication-efficient FO/ZO baselines
(paper §4.2: DSGD-LoRA / ChocoSGD-LoRA / DZSGD-LoRA; App. B.3: r=8, α=16,
q_proj+v_proj targets).

Adapters are a separate small pytree {leaf_path: {"A": (…,n,r), "B": (…,r,m)}};
``merge`` materializes W + (α/r)·A@B (fine at simulator scale — baselines
gossip only the adapter tree, which is what their ledger charges).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import params as plib
from repro.models.params import LeafSpec


DEFAULT_TARGETS = ("wq", "wv")


def lora_spec(spec: Any, targets=DEFAULT_TARGETS, r: int = 8) -> dict[str, Any]:
    flat = plib.flatten_paths(spec)
    out: dict[str, Any] = {}
    for path, leaf in flat.items():
        if not isinstance(leaf, LeafSpec):
            continue
        name = path.split("/")[-1]
        if name not in targets or len(leaf.shape) - leaf.n_batch_dims != 2:
            continue
        batch = leaf.shape[:leaf.n_batch_dims]
        baxes = leaf.axes[:leaf.n_batch_dims]
        n, m = leaf.shape[-2], leaf.shape[-1]
        out[path + "/A"] = LeafSpec(batch + (n, r), baxes + (leaf.axes[-2], "lora"),
                                    n_batch_dims=leaf.n_batch_dims, scale=0.01)
        out[path + "/B"] = LeafSpec(batch + (r, m), baxes + ("lora", leaf.axes[-1]),
                                    n_batch_dims=leaf.n_batch_dims, init="zeros")
    return plib.nest(out)


def lora_init(lspec: Any, seed: int = 0) -> Any:
    return plib.init_params(lspec, seed)


def merge(params: Any, lora: Any, alpha: float = 16.0) -> Any:
    """W_eff = W + (α/r)·A@B for every adapted leaf."""
    lora_flat = plib.flatten_paths(lora)
    adapted: dict[str, jax.Array] = {}
    # sorted: path strings hash with per-process salt, so bare set order
    # would vary across runs (values are keyed lookups either way, but
    # deterministic build order keeps the tree reproducible bit-for-bit)
    for path in sorted({p.rsplit("/", 1)[0] for p in lora_flat}):
        A = lora_flat[path + "/A"]
        B = lora_flat[path + "/B"]
        r = A.shape[-1]
        adapted[path] = (alpha / r) * jnp.einsum("...nr,...rm->...nm", A, B)

    def visit(path: str, leaf: jax.Array):
        if path in adapted:
            return leaf + adapted[path].astype(leaf.dtype)
        return leaf

    from repro.core import seeds as seedlib
    return seedlib.map_with_paths(visit, params)


def n_lora_params(lspec: Any) -> int:
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(lspec))
