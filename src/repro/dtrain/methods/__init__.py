"""Method registry: name -> (method factory, transport factory, config rules).

Every §4.2 protocol is one :class:`MethodSpec` composing a Method plugin
with a Transport plugin — the table DESIGN.md §4 renders.  Adding a
training scenario means appending one entry here; the Trainer loop, churn
handling, checkpointing, and RunResult assembly are inherited.

``consumes`` lists the *method-specific* DTrainConfig fields a spec
actually reads; ``repro.dtrain.runner.validate_config`` rejects non-default
values of any other method-specific field instead of dropping them on the
floor (shared fields — steps, lr, topology, … — are always legal).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.transport import (FloodTransport, GossipSRTransport,
                                  GossipTransport, NullTransport)
from repro.dtrain.api import Method, Setup, Transport
from repro.dtrain.methods.central_zo import CentralZOMethod
from repro.dtrain.methods.gossip import (FirstOrderStep, GossipMethod,
                                         LoRAAdapter, ZeroOrderStep)
from repro.dtrain.methods.gossip_sr import GossipSRMethod
from repro.dtrain.methods.seedflood import SeedFloodMethod


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    name: str
    make_method: Callable[..., Method]           # (cfg) -> Method
    make_transport: Callable[..., Transport]     # (cfg, setup) -> Transport
    consumes: frozenset = frozenset()            # method-specific cfg fields
    supports_churn: bool = False


def _flood_transport(cfg, setup: Setup) -> FloodTransport:
    return FloodTransport(setup.graph, backend=cfg.flood_backend,
                          flood_k=cfg.flood_k)


def _gossip_transport(density=None):
    def make(cfg, setup: Setup) -> GossipTransport:
        return GossipTransport(setup.graph, setup.W, every=cfg.local_iters,
                               choco_density=density(cfg) if density else None,
                               churn_aware=cfg.churn is not None)
    return make


def _gossip_sr_transport(cfg, setup: Setup) -> GossipSRTransport:
    return GossipSRTransport(setup.graph, setup.W, every=cfg.local_iters)


def _null_transport(cfg, setup: Setup) -> NullTransport:
    return NullTransport(cfg.n_clients)


def _gossip_spec(name: str, *, zeroth_order: bool, use_lora: bool,
                 choco: bool) -> MethodSpec:
    local_cls = ZeroOrderStep if zeroth_order else FirstOrderStep

    def make_method(cfg) -> GossipMethod:
        adapter = (LoRAAdapter(cfg.lora_r, cfg.lora_alpha) if use_lora
                   else None)
        return GossipMethod(cfg, name, local_cls(), adapter)

    consumes = {"trace", "sim_latency_s"}
    if choco:
        consumes.add("choco_density")
    if use_lora:
        consumes |= {"lora_r", "lora_alpha"}
    return MethodSpec(
        name=name, make_method=make_method,
        make_transport=_gossip_transport(
            (lambda cfg: cfg.choco_density) if choco else None),
        consumes=frozenset(consumes), supports_churn=True)


METHOD_SPECS: dict[str, MethodSpec] = {
    "seedflood": MethodSpec(
        name="seedflood", make_method=SeedFloodMethod,
        make_transport=_flood_transport,
        consumes=frozenset({"flood_k", "flood_backend", "batched_step",
                            "epoch_replay", "drain", "kernel_backend",
                            "trace", "sim_latency_s", "sim_churn_step_s"}),
        supports_churn=True),
    "dsgd": _gossip_spec("dsgd", zeroth_order=False, use_lora=False,
                         choco=False),
    "dzsgd": _gossip_spec("dzsgd", zeroth_order=True, use_lora=False,
                          choco=False),
    "choco": _gossip_spec("choco", zeroth_order=False, use_lora=False,
                          choco=True),
    "dsgd_lora": _gossip_spec("dsgd_lora", zeroth_order=False, use_lora=True,
                              choco=False),
    "dzsgd_lora": _gossip_spec("dzsgd_lora", zeroth_order=True, use_lora=True,
                               choco=False),
    "choco_lora": _gossip_spec("choco_lora", zeroth_order=False,
                               use_lora=True, choco=True),
    "gossip_sr": MethodSpec(
        name="gossip_sr", make_method=GossipSRMethod,
        make_transport=_gossip_sr_transport,
        consumes=frozenset({"kernel_backend"})),
    "central_zo": MethodSpec(
        name="central_zo", make_method=CentralZOMethod,
        make_transport=_null_transport,
        consumes=frozenset({"momentum", "kernel_backend"})),
}
