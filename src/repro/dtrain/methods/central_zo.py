"""Centralized SubCGE-ZO oracle as a Method plugin.

n perturbations per step, averaging the n two-point estimates —
mathematically identical to SeedFlood under full flooding (same seeds, same
batches), which is what the tier-1 equivalence test pins.  Composes with
``NullTransport`` (no communication, zero bytes).  Also hosts the
beyond-paper subspace momentum (velocity in the r×r coefficient space).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeds as seedlib, subcge
from repro.dtrain.api import MethodBase, Outbox, Setup
from repro.models import params as plib
from repro.models import transformer as tf
from repro.models.perturb import nest_subspace, sample_pert


@dataclasses.dataclass
class CentralZOState:
    params: Any
    velocity: dict[str, jax.Array]


class CentralZOMethod(MethodBase):
    name = "central_zo"

    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, setup: Setup) -> CentralZOState:
        cfg = self.cfg
        n = cfg.n_clients
        arch, meta, scfg = setup.arch, setup.meta, setup.scfg

        kb = scfg.kernel_backend

        @jax.jit
        def step_fn(params, velocity, batch, seeds_t, step):
            sub = subcge.subspace_at_step(meta, scfg, cfg.seed, step)
            sub_n = nest_subspace(sub)
            def one(toks, sd):
                pert = sample_pert(meta, scfg, sd, scfg.eps)
                lp = tf.lm_loss(arch, params, {"tokens": toks}, sub=sub_n,
                                pert=pert, kernel_backend=kb)
                lm = tf.lm_loss(arch, params, {"tokens": toks}, sub=sub_n,
                                pert=pert.with_scale(-scfg.eps),
                                kernel_backend=kb)
                return (lp - lm) / (2 * scfg.eps), 0.5 * (lp + lm)
            alphas, losses = jax.vmap(one)(batch["tokens"], seeds_t)
            coefs = -cfg.lr * alphas / n
            if cfg.momentum > 0.0:
                # beyond-paper: momentum in the r×r coefficient space (O(r²)
                # state/leaf, consensus-safe; velocity resets at τ-refresh
                # since it is only meaningful within its subspace window)
                is_refresh = jnp.logical_and(step > 0,
                                             step % scfg.refresh_period == 0)
                velocity = {p: jnp.where(is_refresh, jnp.zeros_like(v), v)
                            for p, v in velocity.items()}
                new, velocity = subcge.momentum_apply(
                    params, meta, scfg, sub, velocity, seeds_t, coefs,
                    beta=cfg.momentum)
            else:
                new = subcge.apply_messages(params, meta, scfg, sub, seeds_t,
                                            coefs)
            return new, velocity, jnp.mean(losses)

        self._step_fn = step_fn
        params = jax.tree.map(lambda l: l[0], setup.stacked)
        return CentralZOState(params=params,
                              velocity=subcge.zero_buffers(meta, scfg))

    def local_step(self, state: CentralZOState, batch, active, t):
        seeds_t = jnp.asarray(
            seedlib.client_seeds(self.cfg.seed, t, self.cfg.n_clients))
        params, velocity, loss = self._step_fn(state.params, state.velocity,
                                               batch, seeds_t, t)
        return (CentralZOState(params=params, velocity=velocity),
                Outbox(losses=np.asarray(loss).reshape(1)))

    def apply_inbox(self, state: CentralZOState, inbox):
        return state

    def params_of(self, state: CentralZOState):
        return jax.tree.map(lambda l: l[None], state.params)

    def result_extra(self, state: CentralZOState) -> dict:
        return {"final_params": state.params}

    # -- checkpointing --------------------------------------------------------
    # velocity keys are '/'-joined leaf paths; the npz nesting splits them,
    # so load re-flattens the restored subtree back to path-keyed form.

    def state_tree(self, state: CentralZOState):
        return {"params": state.params, "velocity": state.velocity}

    def load_state(self, state: CentralZOState, tree, meta) -> CentralZOState:
        velocity = {p: jnp.asarray(v)
                    for p, v in plib.flatten_paths(tree["velocity"]).items()}
        return CentralZOState(
            params=jax.tree.map(jnp.asarray, tree["params"]),
            velocity=velocity)
