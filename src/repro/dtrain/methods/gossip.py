"""Gossip-averaging baselines as ONE Method composed from strategy parts.

The monolith's ``zeroth_order``/``use_lora``/``choco`` flag triple becomes
composition:

* a *local-update strategy* — :class:`FirstOrderStep` (autodiff SGD) or
  :class:`ZeroOrderStep` (MeZO-style two-point estimate);
* an optional :class:`LoRAAdapter` that narrows the trainable pytree to
  adapters merged into frozen base weights at evaluation time;
* compression is NOT a method concern: Choco lives entirely in
  ``GossipTransport`` (it compresses what crosses the wire, not how a
  client steps).

So ``dsgd`` = FO, ``dzsgd`` = ZO, ``dsgd_lora`` = FO+LoRA, … — six
registry entries over two strategy classes and one adapter, instead of six
hand-rolled loops.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeds as seedlib, zo
from repro.dtrain import lora as loralib
from repro.dtrain.api import MethodBase, Outbox, Setup, freeze_offline
from repro.models import transformer as tf


@dataclasses.dataclass
class GossipState:
    base: Any          # stacked pretrained weights (frozen under LoRA)
    trainable: Any     # stacked trainable pytree (full params or adapters)


class LoRAAdapter:
    """Narrows training+gossip to rank-r q/v adapters (paper §4.2 LoRA rows)."""

    def __init__(self, r: int, alpha: float):
        self.r = r
        self.alpha = alpha

    def init_trainable(self, setup: Setup):
        lspec = loralib.lora_spec(setup.spec, r=self.r)
        l0 = loralib.lora_init(lspec, setup.cfg.seed + 1)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (setup.cfg.n_clients,) + l.shape), l0)

    def full_params(self, base_i, lora_i):
        return loralib.merge(base_i, lora_i, self.alpha)


class ZeroOrderStep:
    """MeZO-style two-point local step (DZSGD): one shared-seed Gaussian
    direction per client per step."""

    needs_seeds = True

    def build(self, cfg, arch, adapter: LoRAAdapter | None):
        @jax.jit
        def local_steps(base, trainable, batch, seeds_t):
            def one(b_i, tr_i, toks, sd):
                if adapter is not None:
                    loss_fn = lambda l: tf.lm_loss(
                        arch, adapter.full_params(b_i, l), {"tokens": toks})
                else:
                    loss_fn = lambda p: tf.lm_loss(arch, p, {"tokens": toks})
                z = zo.mezo_z(tr_i, sd)
                lp = loss_fn(zo.tree_add_scaled(tr_i, z, cfg.eps))
                lm = loss_fn(zo.tree_add_scaled(tr_i, z, -cfg.eps))
                a = (lp - lm) / (2 * cfg.eps)
                return zo.tree_add_scaled(tr_i, z, -cfg.lr * a), 0.5 * (lp + lm)
            return jax.vmap(one)(base, trainable, batch["tokens"], seeds_t)
        return local_steps


class FirstOrderStep:
    """Plain autodiff SGD local step (DSGD / Choco)."""

    needs_seeds = False

    def build(self, cfg, arch, adapter: LoRAAdapter | None):
        @jax.jit
        def local_steps(base, trainable, batch):
            def one(b_i, tr_i, toks):
                if adapter is not None:
                    loss_fn = lambda l: tf.lm_loss(
                        arch, adapter.full_params(b_i, l), {"tokens": toks})
                else:
                    loss_fn = lambda p: tf.lm_loss(arch, p, {"tokens": toks})
                loss, g = jax.value_and_grad(loss_fn)(tr_i)
                new = jax.tree.map(lambda p, gg: p - cfg.lr * gg.astype(p.dtype),
                                   tr_i, g)
                return new, loss
            return jax.vmap(one, in_axes=(0, 0, 0))(base, trainable,
                                                    batch["tokens"])
        return local_steps


class GossipMethod(MethodBase):
    def __init__(self, cfg, name: str, local, adapter: LoRAAdapter | None = None):
        self.cfg = cfg
        self.name = name
        self.local = local
        self.adapter = adapter
        self.churn_aware = cfg.churn is not None

    def init(self, setup: Setup) -> GossipState:
        trainable = (self.adapter.init_trainable(setup)
                     if self.adapter is not None else setup.stacked)
        self._local_steps = self.local.build(self.cfg, setup.arch, self.adapter)
        return GossipState(base=setup.stacked, trainable=trainable)

    def initial_payload(self, state: GossipState):
        return state.trainable

    def local_step(self, state: GossipState, batch, active, t):
        cfg = self.cfg
        if self.local.needs_seeds:
            seeds_t = jnp.asarray(
                seedlib.client_seeds(cfg.seed, t, cfg.n_clients))
            new_trainable, stat = self._local_steps(state.base, state.trainable,
                                                    batch, seeds_t)
        else:
            new_trainable, stat = self._local_steps(state.base, state.trainable,
                                                    batch)
        # churn: offline clients freeze (no local step); without churn the
        # mask is statically all-ones and the guard keeps the hot path clean.
        # The mask check also covers a directly composed run whose
        # churn_aware flag was left False (freeze with all-online is a
        # bitwise no-op, so parity with the monolith is unaffected).
        if self.churn_aware or not active.all():
            new_trainable = freeze_offline(new_trainable, state.trainable,
                                           active)
        state = dataclasses.replace(state, trainable=new_trainable)
        return state, Outbox(losses=np.asarray(stat), payload=new_trainable)

    def apply_inbox(self, state: GossipState, inbox):
        if inbox is None:
            return state
        return dataclasses.replace(state, trainable=inbox)

    def params_of(self, state: GossipState):
        if self.adapter is not None:
            return jax.vmap(self.adapter.full_params)(state.base,
                                                      state.trainable)
        return state.trainable

    # -- checkpointing --------------------------------------------------------
    # base is the deterministic broadcast of the seed-0 init — recomputed by
    # init() at resume, so only the trainable pytree is checkpointed.

    def state_tree(self, state: GossipState):
        return {"trainable": state.trainable}

    def load_state(self, state: GossipState, tree, meta) -> GossipState:
        return dataclasses.replace(
            state, trainable=jax.tree.map(jnp.asarray, tree["trainable"]))
