"""SeedFlood (Algorithm 1) as a Method plugin.

The math of blocks (A)+(B)+(C): per step, one fused donated-buffer jit
dispatch computes every client's ZO estimate, the -η·α/n_eff coefficients,
and each online client's own local update over the stacked client axis
(offline clients get coefficient 0 — an exact no-op, which is this method's
offline-freeze); the outbox is the per-client seed–scalar messages, and
``apply_inbox`` replays the transport's padded ``(n, K)`` payload matrices
epoch-correctly (vmap of ``apply_messages_epoch``).  The per-client
reference path (``batched_step=False``) and the pinned legacy
receiver-epoch replay (``epoch_replay=False``) survive for parity and
regression tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flood, seeds as seedlib, subcge
from repro.core.messages import Message
from repro.core.transport import FloodInbox
from repro.dtrain.api import MethodBase, Outbox, Setup
from repro.models import transformer as tf
from repro.models.perturb import epoch_subspace, nest_subspace, sample_pert


class SeedFloodMethod(MethodBase):
    name = "seedflood"

    def __init__(self, cfg):
        self.cfg = cfg

    # -- jitted pieces --------------------------------------------------------

    def init(self, setup: Setup):
        cfg = self.cfg
        self.n = cfg.n_clients
        meta, scfg, arch = setup.meta, setup.scfg, setup.arch
        self.meta, self.scfg = meta, scfg

        kb = scfg.kernel_backend   # captured at trace time by the fresh
        #                            per-run jits below — no silent flips

        def local_estimate(params_i, batch_i, seed_i, sub):
            pert = sample_pert(meta, scfg, seed_i, scfg.eps)
            lp = tf.lm_loss(arch, params_i, batch_i, sub=sub, pert=pert,
                            kernel_backend=kb)
            lm = tf.lm_loss(arch, params_i, batch_i, sub=sub,
                            pert=pert.with_scale(-scfg.eps),
                            kernel_backend=kb)
            return (lp - lm) / (2 * scfg.eps), 0.5 * (lp + lm)

        # (A)+(B) fused, batched path: one dispatch over the stacked client
        # axis computes every ZO estimate, the -η·α/n_eff coefficients, and
        # each online client's own local update (offline clients get coef 0,
        # an exact no-op).  Buffers are donated — params update in place.
        @functools.partial(jax.jit, donate_argnums=(0,))
        def estimate_and_update(stacked, tokens, seeds_t, step, active_f):
            sub = subcge.subspace_at_step(meta, scfg, cfg.seed, step)
            sub_n = nest_subspace(sub)
            alphas, losses = jax.vmap(
                lambda p, b, sd: local_estimate(p, {"tokens": b}, sd, sub_n)
            )(stacked, tokens, seeds_t)
            n_eff = jnp.maximum(jnp.sum(active_f), 1.0)
            coefs = -cfg.lr * alphas / n_eff
            own = jnp.where(active_f > 0, coefs, 0.0)
            new = jax.vmap(lambda p, sd, c: subcge.apply_messages(
                p, meta, scfg, sub, sd[None], c[None]))(stacked, seeds_t, own)
            return new, losses, coefs

        # estimate only — the per-client reference path updates in a host loop
        @jax.jit
        def estimate_all(stacked, tokens, seeds_t, step):
            sub_n = epoch_subspace(meta, scfg, cfg.seed, step)
            return jax.vmap(
                lambda p, b, sd: local_estimate(p, {"tokens": b}, sd, sub_n)
            )(stacked, tokens, seeds_t)

        @jax.jit
        def update_one(p, sds, cfs, step):
            sub = subcge.subspace_at_step(meta, scfg, cfg.seed, step)
            return subcge.apply_messages(p, meta, scfg, sub, sds, cfs)

        # (C) replay: every received message under ITS SENDER's subspace
        # epoch — the reconstruction guarantee survives τ-refresh boundaries
        # (delayed flooding, anti-entropy catch-up).  Batched variant is one
        # dispatch over the (n, K) padded payload matrices; jax's shape cache
        # bounds retraces because K and E are pow2-bucketed.
        @functools.partial(jax.jit, donate_argnums=(0,))
        def replay_batched(stacked, sds, cfs, stp, epochs):
            return jax.vmap(
                lambda p, sd, cf, st: subcge.apply_messages_epoch(
                    p, meta, scfg, cfg.seed, sd, cf, st, epochs)
            )(stacked, sds, cfs, stp)

        @jax.jit
        def replay_one(p, sds, cfs, stp, epochs):
            return subcge.apply_messages_epoch(p, meta, scfg, cfg.seed,
                                               sds, cfs, stp, epochs)

        self._estimate_and_update = estimate_and_update
        self._estimate_all = estimate_all
        self._update_one = update_one
        self._replay_batched = replay_batched
        self._replay_one = replay_one
        return setup.stacked

    # -- Method protocol ------------------------------------------------------

    def local_step(self, stacked, batch, active, t):
        cfg, n = self.cfg, self.n
        seeds_np = seedlib.client_seeds(cfg.seed, t, n)   # hoisted: no retrace
        seeds_t = jnp.asarray(seeds_np)

        if cfg.batched_step:
            stacked, losses, coefs_j = self._estimate_and_update(
                stacked, batch["tokens"], seeds_t, t,
                jnp.asarray(active, jnp.float32))
            coefs = np.asarray(coefs_j)
        else:
            alphas, losses = self._estimate_all(stacked, batch["tokens"],
                                                seeds_t, t)
            n_eff = max(int(active.sum()), 1)   # == n on a static topology
            # float32 like the fused path (numpy would silently promote)
            coefs = (-cfg.lr * np.asarray(alphas) / n_eff).astype(np.float32)
            # (B) local update: each online client applies its own message
            # immediately; offline clients freeze (no step, no message)
            new_stacked = []
            for i in range(n):
                p_i = jax.tree.map(lambda l: l[i], stacked)
                if active[i]:
                    p_i = self._update_one(p_i, seeds_t[i:i + 1],
                                           jnp.asarray(coefs[i:i + 1]), t)
                new_stacked.append(p_i)
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new_stacked)

        # (C) online clients inject their fresh messages into the flood
        outbox = [(i, Message(seed=int(seeds_np[i]), coef=float(coefs[i]),
                              origin=i, step=t))
                  for i in range(n) if active[i]]
        return stacked, Outbox(losses=np.asarray(losses), payload=outbox)

    def apply_inbox(self, stacked, inbox: FloodInbox | None):
        if inbox is None:
            return stacked
        sds, cfs, stp, t = inbox.seeds, inbox.coefs, inbox.steps, inbox.t
        if sds.shape[1] == 0:
            return stacked
        if not self.cfg.epoch_replay:
            # legacy receiver-step replay (regression demonstration only):
            # pin every live message to the receiver's current epoch
            stp = np.where(cfs != 0.0, np.int32(t), np.int32(flood.STEP_PAD))
        epochs = jnp.asarray(subcge.epoch_slots(stp, self.scfg))  # sfcheck: noqa[SF010] -- epoch_replay=False above IS the PR 2 bug, kept as the A/B regression arm (DESIGN.md §8); the default path reaches here with inbox.steps untouched and tests pin the divergence across a τ boundary
        if self.cfg.batched_step:
            return self._replay_batched(stacked, jnp.asarray(sds),
                                        jnp.asarray(cfs), jnp.asarray(stp),
                                        epochs)
        new_stacked = []
        for i in range(self.n):
            p_i = jax.tree.map(lambda l: l[i], stacked)
            if (cfs[i] != 0.0).any():
                p_i = self._replay_one(p_i, jnp.asarray(sds[i]),
                                       jnp.asarray(cfs[i]),
                                       jnp.asarray(stp[i]), epochs)
            new_stacked.append(p_i)
        return jax.tree.map(lambda *ls: jnp.stack(ls), *new_stacked)

    def params_of(self, stacked):
        return stacked

    def label(self, transport_stats: dict) -> str:
        k = (self.cfg.flood_k if self.cfg.flood_k is not None
             else transport_stats.get("diameter"))
        return f"seedflood(k={k})"

    def result_extra(self, stacked) -> dict:
        return {"final_stacked": stacked}

    def wall_handle(self, stacked):
        return stacked

    # -- checkpointing --------------------------------------------------------

    def state_tree(self, stacked):
        return {"stacked": stacked}

    def load_state(self, stacked, tree, meta):
        return jax.tree.map(jnp.asarray, tree["stacked"])
