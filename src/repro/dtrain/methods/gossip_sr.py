"""Gossip with shared randomness (§3.2 strawman) as a Method plugin.

Each client keeps a per-uid coefficient ledger; the transport averages full
histories under the mixing matrix (O(t·n) comm), and ``apply_inbox``
re-applies the coefficient *deltas* message-by-message — the O(t·n·d)
compute blow-up the paper contrasts against SeedFlood, measured by the
``reconstructions`` counter.  Delta replay is epoch-correct: a reweighted
coefficient for message (i, t0) re-applies under the subspace of ITS origin
step t0, since history reweighting routinely reaches across τ boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flood, seeds as seedlib, subcge
from repro.dtrain.api import MethodBase, Outbox, Setup
from repro.models import transformer as tf
from repro.models.perturb import epoch_subspace, sample_pert


@dataclasses.dataclass
class GossipSRState:
    stacked: Any
    hist: list[dict]        # per-client: uid -> [seed, alpha_scaled, coef_i]
    applied: list[dict]     # per-client: uid -> coef already folded into θ_i
    reconstructions: int = 0


class GossipSRMethod(MethodBase):
    name = "gossip_sr"

    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, setup: Setup) -> GossipSRState:
        cfg = self.cfg
        self.n = cfg.n_clients
        arch, meta, scfg = setup.arch, setup.meta, setup.scfg
        self.scfg = scfg

        kb = scfg.kernel_backend

        @jax.jit
        def estimate_all(stacked_p, batch, seeds_t, step):
            sub = epoch_subspace(meta, scfg, cfg.seed, step)
            def one(p, toks, sd):
                pert = sample_pert(meta, scfg, sd, scfg.eps)
                lp = tf.lm_loss(arch, p, {"tokens": toks}, sub=sub, pert=pert,
                                kernel_backend=kb)
                lm = tf.lm_loss(arch, p, {"tokens": toks}, sub=sub,
                                pert=pert.with_scale(-scfg.eps),
                                kernel_backend=kb)
                return (lp - lm) / (2 * scfg.eps), 0.5 * (lp + lm)
            return jax.vmap(one)(stacked_p, batch["tokens"], seeds_t)

        @jax.jit
        def apply_deltas_fn(p, ss, cc, stp, epochs):
            return subcge.apply_messages_epoch(p, meta, scfg, cfg.seed,
                                               ss, cc, stp, epochs)

        self._estimate_all = estimate_all
        self._apply_deltas_fn = apply_deltas_fn
        return GossipSRState(stacked=setup.stacked,
                             hist=[dict() for _ in range(self.n)],
                             applied=[dict() for _ in range(self.n)])

    def _apply_deltas(self, p_i, sds, cfs, sts):
        K = flood.pad_pow2(len(sds))
        pad_s = np.zeros(K, np.uint32); pad_s[:len(sds)] = sds
        pad_c = np.zeros(K, np.float32); pad_c[:len(cfs)] = cfs
        pad_t = np.full(K, flood.STEP_PAD, np.int32); pad_t[:len(sts)] = sts
        epochs = jnp.asarray(subcge.epoch_slots(pad_t, self.scfg))
        return self._apply_deltas_fn(p_i, jnp.asarray(pad_s),
                                     jnp.asarray(pad_c), jnp.asarray(pad_t),
                                     epochs)

    def local_step(self, state: GossipSRState, batch, active, t):
        cfg, n = self.cfg, self.n
        seeds_np = seedlib.client_seeds(cfg.seed, t, n)
        seeds_t = jnp.asarray(seeds_np)
        alphas, losses = self._estimate_all(state.stacked, batch, seeds_t, t)
        alphas = np.asarray(alphas)
        for i in range(n):
            uid = (i, t)
            state.hist[i][uid] = [int(seeds_np[i]), float(-cfg.lr * alphas[i]),
                                  1.0]
        return state, Outbox(losses=np.asarray(losses), payload=state.hist)

    def apply_inbox(self, state: GossipSRState, inbox):
        if inbox is not None:
            state = dataclasses.replace(state, hist=inbox)
        # incremental re-application of coefficient deltas: O(t·n·d) — the
        # §3.2 cost blow-up, measured
        n = self.n
        reconstructions = state.reconstructions
        new_stacked = []
        for i in range(n):
            p_i = jax.tree.map(lambda l: l[i], state.stacked)
            sds, cfs, sts = [], [], []
            for uid, (sd, a_scaled, c) in state.hist[i].items():
                prev = state.applied[i].get(uid, 0.0)
                delta = c * a_scaled - prev
                if abs(delta) > 0:
                    sds.append(sd); cfs.append(delta); sts.append(uid[1])
                    state.applied[i][uid] = c * a_scaled
            if sds:
                reconstructions += len(sds)
                p_i = self._apply_deltas(p_i, np.asarray(sds, np.uint32),
                                         np.asarray(cfs, np.float32),
                                         np.asarray(sts, np.int32))
            new_stacked.append(p_i)
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new_stacked)
        return dataclasses.replace(state, stacked=stacked,
                                   reconstructions=reconstructions)

    def params_of(self, state: GossipSRState):
        return state.stacked

    def result_extra(self, state: GossipSRState) -> dict:
        return {"reconstructions": state.reconstructions}

    # -- checkpointing --------------------------------------------------------
    # uid keys are (origin, step) tuples; JSON flattens each ledger to an
    # insertion-ordered [origin, step, seed, alpha_scaled, coef] list so the
    # restored dicts iterate (and therefore re-apply deltas) in the same
    # order — float-sum order is part of bitwise reproducibility.

    def state_tree(self, state: GossipSRState):
        return {"stacked": state.stacked}

    def state_meta(self, state: GossipSRState) -> dict:
        return {
            "hist": [[[o, t, sd, a, c] for (o, t), (sd, a, c) in h.items()]
                     for h in state.hist],
            "applied": [[[o, t, c] for (o, t), c in a.items()]
                        for a in state.applied],
            "reconstructions": state.reconstructions,
        }

    def load_state(self, state: GossipSRState, tree, meta) -> GossipSRState:
        return GossipSRState(
            stacked=jax.tree.map(jnp.asarray, tree["stacked"]),
            hist=[{(int(o), int(t)): [int(sd), float(a), float(c)]
                   for o, t, sd, a, c in h} for h in meta["hist"]],
            applied=[{(int(o), int(t)): float(c) for o, t, c in a}
                     for a in meta["applied"]],
            reconstructions=int(meta["reconstructions"]))
