"""The Method × Transport plugin API of the decentralized trainer (DESIGN.md §4).

The paper's central claim is an algorithm/transport separation: the same
SubCGE-ZO local step stays exact whether its seed–scalar messages arrive by
full flood, delayed flood, or anti-entropy catch-up.  This module makes that
separation a code contract:

* a :class:`Method` owns the *math* of one training algorithm — how a client
  turns a batch into new local state and an outbox, and how it folds a
  transport's inbox back in;
* a ``Transport`` (see :mod:`repro.core.transport`) owns the *network* — it
  moves outboxes, applies churn to the topology, and is the only layer that
  touches a :class:`~repro.core.messages.CommLedger`;
* the :class:`~repro.dtrain.trainer.Trainer` owns the *loop* — churn
  scheduling, loss/eval logging, checkpointing, drain, wall-clock, and
  :class:`RunResult` assembly — once, for every method.

A new training scenario is one new ``Method`` (and, if it speaks a new wire
format, one new ``Transport``) registered in
:data:`repro.dtrain.methods.METHOD_SPECS`; the step loop is never forked.

Contract details the protocols cannot express in types:

* ``local_step`` receives the live ``active`` mask and must make offline
  clients exact no-ops (freeze their parameters, emit nothing for them).
  :func:`freeze_offline` is the shared helper; SeedFlood instead masks
  coefficients to zero inside its fused step, which is bitwise equivalent.
* ``Outbox.payload`` is transport-specific and opaque to the Trainer:
  flooding methods emit ``(client, Message)`` pairs, gossip methods emit the
  stacked trainable pytree, gossip-SR emits coefficient histories, and the
  null transport ignores it.
* ``apply_inbox`` must accept ``inbox=None`` (the transport had nothing to
  deliver this step — e.g. gossip between mixing rounds).
* ``state_tree``/``state_meta``/``load_state`` make method state
  checkpointable: the tree holds arrays (saved via ``checkpoint/ckpt.py``),
  the meta holds JSON-serializable scalars.  A resumed run must be
  bitwise-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, uniform_dense
from repro.core import gossip
from repro.core.subcge import SubCGEConfig
from repro.data import synthetic
from repro.models import params as plib
from repro.models import transformer as tf
from repro.topology import graphs


def sim_arch(vocab: int = 256, d_model: int = 64, n_layers: int = 2,
             n_heads: int = 4, d_ff: int = 128) -> ArchConfig:
    """Tiny dense decoder for simulator experiments (the paper's OPT stand-in)."""
    return uniform_dense("sim-tiny", n_layers=n_layers, d_model=d_model,
                         n_heads=n_heads, n_kv=n_heads, d_ff=d_ff,
                         vocab=vocab, tie_embeddings=True, max_seq=128)


class Setup:
    """Shared run scaffolding: arch, data splits, topology, stacked params.

    Built once per run from a ``DTrainConfig`` and handed to both the method
    (``Method.init``) and the transport factory.
    """

    def __init__(self, cfg):
        self.cfg = cfg
        self.arch = cfg.arch or sim_arch()
        self.task = cfg.task or synthetic.TaskConfig(vocab=self.arch.vocab)
        self.train, self.valid, self.test = synthetic.make_splits(self.task)
        self.parts = synthetic.partition(self.train, cfg.n_clients,
                                         scheme=cfg.partition, seed=cfg.seed)
        self.graph = graphs.make(cfg.topology, cfg.n_clients)
        self.W = graphs.metropolis_weights(self.graph)
        self.spec = tf.arch_spec(self.arch)
        p0 = plib.init_params(self.spec, cfg.seed)
        self.stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (cfg.n_clients,) + l.shape), p0)
        self.meta = plib.subcge_meta(self.spec)
        self.scfg = SubCGEConfig(rank=cfg.subcge_rank,
                                 refresh_period=cfg.subcge_tau, eps=cfg.eps,
                                 kernel_backend=cfg.kernel_backend)
        self.n_params = plib.n_params(self.spec)

    def batches(self, step: int):
        return synthetic.stacked_batches(self.train, self.parts, step,
                                         self.cfg.batch_size, self.cfg.seed)

    def gmp(self, stacked) -> float:
        avg = jax.tree.map(lambda l: l.mean(axis=0), stacked)
        return synthetic.accuracy(self.arch, avg, self.test,
                                  forward_fn=tf.forward)

    def valid_loss(self, stacked) -> float:
        avg = jax.tree.map(lambda l: l.mean(axis=0), stacked)
        toks = jnp.asarray(self.valid.tokens[:128])
        return float(tf.lm_loss(self.arch, avg, {"tokens": toks}))


@dataclasses.dataclass
class RunResult:
    method: str
    gmp: float                      # final averaged-model accuracy
    loss_curve: list[float]
    acc_curve: list[tuple[int, float]]
    bytes_per_edge: float
    total_bytes: float
    consensus_error: float
    wall_s: float
    # jit-compilation wall time of the first executed step, reported apart
    # from extra["step_wall_s"] so bench medians stay steady-state
    compile_wall_s: float = 0.0
    extra: dict = dataclasses.field(default_factory=dict)

    #: extra[] entries excluded from to_json(): whole parameter pytrees that
    #: belong in an .npz checkpoint, not a results file.
    _JSON_DROP = ("final_stacked", "final_params")

    def to_json(self) -> dict:
        """JSON-safe dict: numpy/JAX scalars become Python numbers, arrays
        become lists, and parameter pytrees (``final_stacked``/``final_params``)
        are dropped — so ``json.dumps`` never trips on a non-serializable
        dtype regardless of what a method put in ``extra``."""
        def coerce(x):
            if isinstance(x, (jax.Array, np.ndarray, np.generic)):
                arr = np.asarray(x)
                return arr.item() if arr.ndim == 0 else arr.tolist()
            if isinstance(x, dict):
                return {str(k): coerce(v) for k, v in x.items()}
            if isinstance(x, (list, tuple)):
                return [coerce(v) for v in x]
            if isinstance(x, (bool, int, str)) or x is None:
                return x
            if isinstance(x, float):
                return x
            return str(x)

        extra = {k: v for k, v in self.extra.items() if k not in self._JSON_DROP}
        return coerce({
            "method": self.method, "gmp": self.gmp,
            "loss_curve": self.loss_curve, "acc_curve": self.acc_curve,
            "bytes_per_edge": self.bytes_per_edge,
            "total_bytes": self.total_bytes,
            "consensus_error": self.consensus_error,
            "wall_s": self.wall_s, "compile_wall_s": self.compile_wall_s,
            "extra": extra,
        })


@dataclasses.dataclass
class Outbox:
    """What one local step hands back to the loop: per-model losses (the
    Trainer logs them under the active mask) and a transport payload."""
    losses: np.ndarray
    payload: Any = None


# ---------------------------------------------------------------------------
# protocols
# ---------------------------------------------------------------------------

@runtime_checkable
class Method(Protocol):
    """One training algorithm.  State is opaque to the Trainer — anything
    from a bare stacked-params pytree (SeedFlood) to a dataclass bundling
    histories and velocities."""

    def init(self, setup: Setup) -> Any: ...
    def local_step(self, state: Any, batch: dict, active: np.ndarray,
                   t: int) -> tuple[Any, Outbox]: ...
    def apply_inbox(self, state: Any, inbox: Any) -> Any: ...
    def params_of(self, state: Any) -> Any: ...


@runtime_checkable
class Transport(Protocol):
    """One communication substrate.  Owns the CommLedger: every byte a run
    charges is charged here, never in a Method or the Trainer."""

    def bind(self, init_payload: Any) -> None: ...
    def active_mask(self) -> np.ndarray: ...
    def apply_churn(self, events) -> None: ...
    def exchange(self, payload: Any, t: int, active: np.ndarray) -> Any: ...
    def stats(self) -> dict: ...


class MethodBase:
    """Default hooks so concrete methods only override what they use."""

    name = "method"

    def initial_payload(self, state: Any) -> Any:
        """Payload-equivalent view of the *initial* state, handed to
        ``Transport.bind`` (Choco initializes its surrogate copies from the
        pre-training weights — paper App. B.2)."""
        return None

    def label(self, transport_stats: dict) -> str:
        """RunResult.method display name (may cite transport stats)."""
        return self.name

    def result_extra(self, state: Any) -> dict:
        return {}

    def wall_handle(self, state: Any):
        """Array (tree) the Trainer blocks on for per-step wall timing, or
        None to skip the device sync."""
        return None

    # -- checkpointing --------------------------------------------------------

    def state_tree(self, state: Any) -> Any:
        """Array-valued pytree capturing the method state (ckpt.save)."""
        raise NotImplementedError(f"{self.name} does not support checkpointing")

    def state_meta(self, state: Any) -> dict:
        """JSON-serializable non-array state (histories, counters)."""
        return {}

    def load_state(self, state: Any, tree: Any, meta: dict) -> Any:
        raise NotImplementedError(f"{self.name} does not support checkpointing")


# ---------------------------------------------------------------------------
# shared step helpers (used by methods and the Trainer)
# ---------------------------------------------------------------------------

def freeze_offline(new, old, active: np.ndarray):
    """Keep offline clients' leaves at their pre-step values."""
    mask = jnp.asarray(active)

    def f(a, b):
        m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)

    return jax.tree.map(f, new, old)


def log_step_loss(loss_curve: list[float], losses: np.ndarray,
                  active: np.ndarray) -> None:
    """Mean loss over online clients; under a full outage nobody computed a
    step, so carry the previous loss instead of averaging an empty slice
    (NaN + RuntimeWarning)."""
    if active.any():
        loss_curve.append(float(np.mean(losses[active])))
    else:
        loss_curve.append(loss_curve[-1] if loss_curve else float("nan"))


def active_consensus(stacked, active: np.ndarray) -> float:
    """Consensus error over online clients only (offline params are frozen
    snapshots — counting them would conflate churn with divergence).  The
    mask is clipped to the model axis so single-model methods (central_zo)
    report 0 without pretending to have per-client copies."""
    n_models = jax.tree.leaves(stacked)[0].shape[0]
    idx = np.flatnonzero(active[:n_models])
    if idx.size <= 1:
        return 0.0
    sub = jax.tree.map(lambda l: l[idx], stacked)
    return float(gossip.consensus_error(sub))
