"""The ONE training loop every method runs through (DESIGN.md §4).

The Trainer owns everything the four pre-plugin monoliths each
re-implemented: churn-schedule application, the offline active mask,
loss/eval/consensus logging, checkpoint/resume, end-of-run drain,
per-step wall-clock, and ``RunResult`` assembly.  Methods supply the math
(``local_step``/``apply_inbox``), transports move the bytes; the loop is

    churn events -> local step (+offline freeze) -> log loss
    -> transport exchange -> apply inbox -> eval/ckpt cadence

Checkpointing (``checkpoint_every``/``resume_from``) snapshots method
state, transport state (flood frontiers and message tables included — the
ledger and in-flight delayed-flooding messages are part of run state), and
the logged curves, via ``repro.checkpoint.ckpt``.  A resumed run is
bitwise-identical to an uninterrupted one: every source of randomness is
counter-based in (seed, step), so restoring state and the step counter
restores the trajectory (``tests/test_trainer_api.py`` pins this, τ-epoch
crossings included).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.dtrain.api import (Method, RunResult, Setup, Transport,
                              active_consensus, log_step_loss)
from repro.topology.dynamic import ChurnSchedule


class Trainer:
    """Drives one decentralized run of ``method`` over ``transport``."""

    def __init__(self, cfg, setup: Setup, method: Method,
                 transport: Transport, churn: ChurnSchedule | None = None):
        self.cfg = cfg
        self.setup = setup
        self.method = method
        self.transport = transport
        self.churn = churn

    # -- checkpoint plumbing ---------------------------------------------------

    def _ckpt_path(self, step: int) -> str:
        return os.path.join(self.cfg.checkpoint_dir, f"step{step:06d}.npz")

    def _save_checkpoint(self, step: int, state, curves) -> None:
        loss_curve, acc_curve, consensus_curve, step_wall_s = curves
        tree = {"method": self.method.state_tree(state)}
        tarrs = self.transport.state_arrays()
        if tarrs is not None:
            tree["transport"] = tarrs
        ckpt.save(self._ckpt_path(step), tree, metadata={
            "step": step,
            "method": self.cfg.method,
            "loss_curve": loss_curve,
            "acc_curve": acc_curve,
            "consensus_curve": consensus_curve,
            "step_wall_s": step_wall_s,
            "method_meta": self.method.state_meta(state),
            "transport_meta": self.transport.state_meta(),
        })

    def _resume(self, state, curves):
        # to_jax=False: transport state includes exact int64/float64 arrays
        # (message coefficients, bitsets) that a float32 cast would corrupt;
        # methods re-cast their own params subtrees.
        tree, meta = ckpt.load(self.cfg.resume_from, to_jax=False)
        if meta.get("method") != self.cfg.method:
            raise ValueError(
                f"checkpoint was written by method '{meta.get('method')}', "
                f"cannot resume a '{self.cfg.method}' run from it")
        state = self.method.load_state(state, tree["method"],
                                       meta.get("method_meta") or {})
        self.transport.load_state(tree.get("transport"),
                                  meta.get("transport_meta") or {})
        loss_curve, acc_curve, consensus_curve, step_wall_s = curves
        loss_curve += [float(x) for x in meta["loss_curve"]]
        acc_curve += [(int(s), float(a)) for s, a in meta["acc_curve"]]
        consensus_curve += [(int(s), float(c))
                            for s, c in meta["consensus_curve"]]
        step_wall_s += [float(x) for x in meta["step_wall_s"]]
        return state, int(meta["step"])

    # -- the loop --------------------------------------------------------------

    def run(self) -> RunResult:
        cfg, s, method, transport = self.cfg, self.setup, self.method, \
            self.transport
        state = method.init(s)
        transport.bind(method.initial_payload(state))

        loss_curve: list[float] = []
        acc_curve: list[tuple[int, float]] = []
        consensus_curve: list[tuple[int, float]] = []
        step_wall_s: list[float] = []   # steady-state samples only
        compile_wall_s = 0.0            # first executed step (pays jit compile)
        curves = (loss_curve, acc_curve, consensus_curve, step_wall_s)
        start = 0
        if cfg.resume_from:
            state, start = self._resume(state, curves)
        # wall-clock is reporting-only (wall_s/step_wall_s); every RNG in
        # the run derives from cfg.base_seed — SF001 bans clock-seeding
        t0 = time.time()

        for t in range(start, cfg.steps):
            t_step = time.perf_counter()
            # churn events land at the start of the step; rejoined clients'
            # anti-entropy catch-up rides in this step's exchange
            if self.churn is not None:
                events = self.churn.events_at(t)
                if events:
                    transport.apply_churn(events)
            active = transport.active_mask()

            batch = s.batches(t)
            state, outbox = method.local_step(state, batch, active, t)
            log_step_loss(loss_curve, np.asarray(outbox.losses),
                          active[:len(outbox.losses)])

            inbox = transport.exchange(outbox.payload, t, active)
            state = method.apply_inbox(state, inbox)

            handle = method.wall_handle(state)
            if handle is not None:
                jax.block_until_ready(handle)
            # the first step this process executes pays jit compilation; it
            # goes to compile_wall_s so step_wall_s stays steady-state
            dt = time.perf_counter() - t_step
            if t == start:
                compile_wall_s = dt
            else:
                step_wall_s.append(dt)

            if cfg.eval_every and (t + 1) % cfg.eval_every == 0:
                stacked = method.params_of(state)
                acc_curve.append((t + 1, s.gmp(stacked)))
                consensus_curve.append((t + 1,
                                        active_consensus(stacked, active)))
            if cfg.checkpoint_every and (t + 1) % cfg.checkpoint_every == 0:
                self._save_checkpoint(t + 1, state, curves)

        if cfg.drain:
            # flush in-flight delayed-flooding messages: flood + replay with
            # no new injections until quiescent, so every message is applied
            for inbox in transport.drain(cfg.steps + 1, cfg.steps):
                state = method.apply_inbox(state, inbox)

        active = transport.active_mask()
        stacked = method.params_of(state)
        stats = transport.stats()
        extra = {"n_params": s.n_params, **stats,
                 "consensus_curve": consensus_curve,
                 "step_wall_s": step_wall_s,
                 **method.result_extra(state)}
        return RunResult(
            method=method.label(stats), gmp=s.gmp(stacked),
            loss_curve=loss_curve, acc_curve=acc_curve,
            bytes_per_edge=transport.ledger.per_edge,
            total_bytes=transport.ledger.total_bytes,
            consensus_error=active_consensus(stacked, active),
            wall_s=time.time() - t0, compile_wall_s=compile_wall_s,
            extra=extra)
