"""Checkpointing: flattened-pytree .npz + JSON metadata.

Simple, dependency-free and exact: leaves are saved under their canonical
'/'-joined paths, restored into the reference tree structure.  ZO training
state is just (params, step, global_seed) — there are no optimizer moments
to save, which is itself one of SeedFlood's deployment advantages (a 1T
model checkpoints at 1× param bytes).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as plib


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = plib.flatten_paths(tree)
    arrays = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16 — store as uint16 bits with a dtype marker
            arrays[k + "::bf16"] = arr.view(np.uint16)
        else:
            arrays[k] = arr
    np.savez(path, **arrays)
    with open(path + ".meta.json", "w") as f:
        json.dump(metadata or {}, f, indent=2, default=str)


def load(path: str, like: Any | None = None,
         to_jax: bool = True) -> tuple[Any, dict]:
    """Restore a checkpoint tree (+ its JSON metadata).

    ``to_jax=False`` keeps leaves as the exact numpy arrays that were saved
    — jnp conversion would downcast int64/float64 under disabled x64, which
    matters for trainer/transport state (message coefficients, bitsets),
    not just be a device transfer.
    """
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        flat: dict[str, np.ndarray] = {}
        for k in z.files:
            if k.endswith("::bf16"):
                flat[k[:-6]] = jax.numpy.asarray(z[k]).view(jnp.bfloat16)
            else:
                flat[k] = z[k]
    meta = {}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if not os.path.exists(meta_path):
        meta_path = path + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    tree = plib.nest({k: (jnp.asarray(v) if to_jax else v)
                      for k, v in flat.items()})
    if like is not None:
        ref_flat = plib.flatten_paths(like)
        got_flat = plib.flatten_paths(tree)
        missing = set(ref_flat) - set(got_flat)
        extra = set(got_flat) - set(ref_flat)
        if missing or extra:
            raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                             f"extra={sorted(extra)[:5]}")
        tree = jax.tree.map(lambda r, g: jnp.asarray(g, r.dtype).reshape(r.shape),
                            like, tree)
    return tree, meta
