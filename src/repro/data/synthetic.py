"""Synthetic task suite + deterministic client partitioning.

No external datasets ship in this container, so the paper's SuperGLUE
fine-tuning is replaced by two synthetic-but-learnable tasks with the same
experimental *shape* (few-shot fine-tuning, 1024 train examples partitioned
across clients, fixed validation/test sets, accuracy metric):

* ``classify``  — C latent classes; tokens drawn from class-conditional
  distributions; the final position must predict the class token.  GMP =
  classification accuracy (the paper's task-performance analogue).
* ``markov``    — order-1 Markov language; metric = next-token accuracy.

Partitions are deterministic in (seed, n_clients): uniform (the paper's
setting) or Dirichlet non-IID for heterogeneity studies.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskConfig:
    kind: str = "classify"         # classify | markov
    vocab: int = 256
    seq_len: int = 32
    n_classes: int = 4
    n_train: int = 1024            # paper: 1,024 training samples
    n_valid: int = 500
    n_test: int = 1000
    seed: int = 0
    concentration: float = 0.3     # class-distribution peakiness


@dataclasses.dataclass
class Dataset:
    tokens: np.ndarray             # (N, T) int32 — includes the label slot
    labels: np.ndarray             # (N,) int32 — class token id (classify)
    task: TaskConfig

    def __len__(self) -> int:
        return self.tokens.shape[0]


def _class_distributions(task: TaskConfig, rng: np.random.Generator) -> np.ndarray:
    """Class-conditional token distributions over the non-label vocab."""
    usable = task.vocab - task.n_classes  # class tokens live at the top
    alpha = np.full(usable, task.concentration)
    return rng.dirichlet(alpha, size=task.n_classes)


def make_splits(task: TaskConfig) -> tuple[Dataset, Dataset, Dataset]:
    rng = np.random.default_rng(task.seed)
    if task.kind == "classify":
        dists = _class_distributions(task, rng)

        def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
            cls = rng.integers(task.n_classes, size=n)
            toks = np.stack([
                rng.choice(task.vocab - task.n_classes, size=task.seq_len,
                           p=dists[c]) for c in cls]).astype(np.int32)
            label_tok = (task.vocab - task.n_classes + cls).astype(np.int32)
            toks = np.concatenate([toks, label_tok[:, None]], axis=1)
            return toks, label_tok

        out = []
        for n in (task.n_train, task.n_valid, task.n_test):
            t, l = sample(n)
            out.append(Dataset(t, l, task))
        return tuple(out)  # type: ignore[return-value]

    if task.kind == "markov":
        # sparse-ish random transition matrix, shared across splits
        P = rng.dirichlet(np.full(task.vocab, 0.05), size=task.vocab)

        def sample(n: int) -> np.ndarray:
            toks = np.zeros((n, task.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(task.vocab, size=n)
            for t in range(1, task.seq_len + 1):
                u = rng.random((n, 1))
                cdf = np.cumsum(P[toks[:, t - 1]], axis=1)
                toks[:, t] = (u > cdf).sum(axis=1)
            return toks

        out = []
        for n in (task.n_train, task.n_valid, task.n_test):
            t = sample(n)
            out.append(Dataset(t, t[:, -1].copy(), task))
        return tuple(out)  # type: ignore[return-value]

    raise ValueError(task.kind)


# ---------------------------------------------------------------------------
# client partitioning (paper: uniform partition of 1,024 samples)
# ---------------------------------------------------------------------------

def partition(ds: Dataset, n_clients: int, *, scheme: str = "uniform",
              dirichlet_alpha: float = 0.5, seed: int = 0) -> list[np.ndarray]:
    """Index sets per client.  'uniform' shuffles then splits evenly (the
    paper's setting: {64,32,16,8} samples/client for n={16,32,64,128});
    'dirichlet' skews class proportions per client (non-IID)."""
    rng = np.random.default_rng(seed)
    n = len(ds)
    if scheme == "uniform":
        idx = rng.permutation(n)
        return [np.sort(a) for a in np.array_split(idx, n_clients)]
    if scheme == "dirichlet":
        cls = ds.labels
        classes = np.unique(cls)
        props = rng.dirichlet(np.full(n_clients, dirichlet_alpha), size=len(classes))
        owner = np.zeros(n, np.int32)
        for ci, c in enumerate(classes):
            members = np.where(cls == c)[0]
            rng.shuffle(members)
            cuts = (np.cumsum(props[ci])[:-1] * len(members)).astype(int)
            for k, part in enumerate(np.split(members, cuts)):
                owner[part] = k
        return [np.sort(np.where(owner == k)[0]) for k in range(n_clients)]
    raise ValueError(scheme)


def client_batch(ds: Dataset, part: np.ndarray, client: int, step: int,
                 batch_size: int, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Stateless minibatch: deterministic in (client, step) — exactly the
    B_{i,t} ~ D_i of Algorithm 1, reproducible on any host."""
    rng = np.random.default_rng((seed * 1_000_003 + step) * 131 + client)
    take = rng.choice(part, size=min(batch_size, len(part)),
                      replace=len(part) < batch_size)
    return {"tokens": jnp.asarray(ds.tokens[take])}


def stacked_batches(ds: Dataset, parts: list[np.ndarray], step: int,
                    batch_size: int, seed: int = 0) -> dict[str, jnp.ndarray]:
    """All clients' minibatches stacked on a leading client axis."""
    bs = [client_batch(ds, parts[i], i, step, batch_size, seed)
          for i in range(len(parts))]
    return {"tokens": jnp.stack([b["tokens"] for b in bs])}


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def accuracy(cfg, params, ds: Dataset, *, forward_fn, batch_size: int = 128) -> float:
    """classify: accuracy of the label position restricted to class tokens;
    markov: next-token accuracy at the last position."""
    task = ds.task
    n_cls = task.n_classes
    correct = 0
    for i in range(0, len(ds), batch_size):
        toks = jnp.asarray(ds.tokens[i:i + batch_size])
        logits, _, _ = forward_fn(cfg, params, {"tokens": toks[:, :-1]})
        last = logits[:, -1]
        if task.kind == "classify":
            cls_logits = last[:, task.vocab - n_cls:]
            pred = jnp.argmax(cls_logits, axis=-1) + (task.vocab - n_cls)
        else:
            pred = jnp.argmax(last, axis=-1)
        correct += int((pred == jnp.asarray(ds.labels[i:i + batch_size])).sum())
    return correct / len(ds)
