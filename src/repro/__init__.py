"""repro — SeedFlood: scalable decentralized LLM training in JAX.

Subpackages:
  core        seed-reconstructible ZO updates, SubCGE, flooding, gossip
  models      functional decoder zoo (dense/MoE/SSM/hybrid/VLM/audio)
  configs     assigned architectures + input shapes
  dtrain      decentralized-network simulator (Algorithm 1 + baselines)
  launch      pod runtime: meshes, sharded steps, dry-run, train driver
  kernels     Pallas TPU kernels (+ jnp oracles)
  roofline    analytic cost model + HLO collective analysis
  data/optim/checkpoint/topology   substrates
"""

__version__ = "1.0.0"
