"""Assigned architecture configs (public-literature pool) + the paper's own
OPT models.  Every entry cites its source; every entry has a ``reduced``
variant (≤2 layers, d_model ≤ 512, ≤4 experts) for CPU smoke tests that
preserves the family's layer-type mix.

Registry keys are the assignment ids (e.g. ``--arch jamba-1.5-large-398b``).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, AttnCfg, FrontendCfg, Group,
                                LayerCfg, MambaCfg, MoECfg, dense_layer,
                                uniform_dense)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

QWEN15_05B = uniform_dense(
    "qwen1.5-0.5b", n_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=2816, vocab=151_936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6, sharding_policy="tp",
    source="[hf:Qwen/Qwen1.5-0.5B] 24L d1024 16H(kv16) ff2816 v151936, QKV bias")

TINYLLAMA_11B = uniform_dense(
    "tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32, n_kv=4,
    d_ff=5632, vocab=32_000, rope_theta=1e4, sharding_policy="tp",
    source="[arXiv:2401.02385] 22L d2048 32H(kv4) ff5632 v32000, llama2-arch")

QWEN2_72B = uniform_dense(
    "qwen2-72b", n_layers=80, d_model=8192, n_heads=64, n_kv=8,
    d_ff=29_568, vocab=152_064, qkv_bias=True, rope_theta=1e6,
    # §Perf: pure TP — 145GB bf16 fits 16-way model-sharded (9GB/chip) since
    # ZO training stores no grads/moments; beats fsdp_tp by 6.4x collective
    sharding_policy="tp",
    source="[arXiv:2407.10671] 80L d8192 64H(kv8) ff29568 v152064, GQA+QKV bias")


def _gemma3_groups() -> tuple[Group, ...]:
    """26 layers, 5 local (sw=512) : 1 global -> 4 full periods + 2 local."""
    local = dense_layer(1152, 4, 1, 6912, head_dim=256, window=512)
    glob = dense_layer(1152, 4, 1, 6912, head_dim=256, window=None)
    return (Group((local,) * 5 + (glob,), 4), Group((local,), 2))


GEMMA3_1B = ArchConfig(
    name="gemma3-1b", family="dense", d_model=1152, vocab=262_144,
    groups=_gemma3_groups(), act="gelu", tie_embeddings=True,
    rope_theta=1e6, sharding_policy="tp", long_context_mode="native",
    source="[hf:google/gemma-3-1b-pt] 26L d1152 4H(kv1,hd256) ff6912 "
           "v262144, 5:1 local(sw512):global, 128k ctx")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _kimi_slot() -> LayerCfg:
    return LayerCfg(
        mixer="attn",
        attn=AttnCfg(n_heads=64, n_kv_heads=8, head_dim=128),
        ffn="moe",
        moe=MoECfg(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1,
                   router_aux=0.001))


KIMI_K2 = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", d_model=7168, vocab=163_840,
    groups=(Group((_kimi_slot(),), 61),), rope_theta=5e4,
    sharding_policy="fsdp_tp", moe_gather_weights=True,  # §Perf: 2.3x
    source="[arXiv:2501.kimi2] 61L d7168 64H(kv8) MoE 384e top-8 +1 shared, "
           "expert ff2048, v163840 — 1T total / ~32B active")


def _dsv2_attn() -> AttnCfg:
    return AttnCfg(n_heads=128, n_kv_heads=128, head_dim=128,
                   q_lora=1536, kv_lora=512, rope_head_dim=64, v_head_dim=128)


def _dsv2_groups() -> tuple[Group, ...]:
    dense0 = LayerCfg(mixer="attn", attn=_dsv2_attn(), ffn="dense", d_ff=12_288)
    moe = LayerCfg(mixer="attn", attn=_dsv2_attn(), ffn="moe",
                   moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536,
                              n_shared=2, router_aux=0.001))
    return (Group((dense0,), 1), Group((moe,), 59))


DEEPSEEK_V2 = ArchConfig(
    name="deepseek-v2-236b", family="moe", d_model=5120, vocab=102_400,
    groups=_dsv2_groups(), rope_theta=1e4, sharding_policy="fsdp_tp",
    moe_gather_weights=True,  # §Perf: with mla_latent fix + scatter-add combine: 140x
    source="[arXiv:2405.04434] 60L d5120 128H MLA(q_lora1536,kv_lora512,"
           "rope64) MoE 160e top-6 + 2 shared, expert ff1536, v102400")


# ---------------------------------------------------------------------------
# SSM / hybrid
# ---------------------------------------------------------------------------

def _falcon_mamba_slot() -> LayerCfg:
    return LayerCfg(mixer="mamba",
                    mamba=MambaCfg(d_inner=8192, d_state=16, d_conv=4),
                    ffn="none")


FALCON_MAMBA_7B = ArchConfig(
    name="falcon-mamba-7b", family="ssm", d_model=4096, vocab=65_024,
    groups=(Group((_falcon_mamba_slot(),), 64),), pos="none",
    sharding_policy="tp", long_context_mode="native",
    source="[arXiv:2410.05355] 64L d4096 mamba1 (d_inner 8192, state 16, "
           "conv 4), attention-free, v65024")


def _jamba_groups() -> tuple[Group, ...]:
    """Period of 8: attention at slot 0, Mamba at 1..7; MoE (16e top-2) on
    every other layer [arXiv:2403.19887]."""
    attn = AttnCfg(n_heads=64, n_kv_heads=8, head_dim=128)
    mam = MambaCfg(d_inner=2 * 8192, d_state=16, d_conv=4)
    moe = MoECfg(n_experts=16, top_k=2, d_ff_expert=24_576, router_aux=0.001)
    slots = []
    for idx in range(8):
        mixer = "attn" if idx == 0 else "mamba"
        ffn = "moe" if idx % 2 == 1 else "dense"
        slots.append(LayerCfg(
            mixer=mixer,
            attn=attn if mixer == "attn" else None,
            mamba=mam if mixer == "mamba" else None,
            ffn=ffn, d_ff=24_576 if ffn == "dense" else 0,
            moe=moe if ffn == "moe" else None))
    return (Group(tuple(slots), 9),)


JAMBA_15_LARGE = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid", d_model=8192, vocab=65_536,
    groups=_jamba_groups(), sharding_policy="fsdp_tp", moe_gather_weights=True,
    long_context_mode="native",
    source="[arXiv:2403.19887] 72L d8192 64H(kv8), Mamba:attn 7:1, "
           "MoE 16e top-2 every other layer, ff24576, v65536 — 398B total")


# ---------------------------------------------------------------------------
# audio / vlm (stubbed frontends per spec)
# ---------------------------------------------------------------------------

MUSICGEN_MEDIUM = ArchConfig(
    name="musicgen-medium", family="audio", d_model=1536, vocab=2048,
    groups=(Group((dense_layer(1536, 24, 24, 6144),), 48),),
    gated_mlp=False, act="gelu", norm="layernorm", pos="sinusoidal",
    sharding_policy="tp",
    frontend=FrontendCfg(kind="audio_cond", n_embeds=64, embed_dim=768,
                         source="T5-encoder conditioning (stub)"),
    source="[arXiv:2306.05284] 48L d1536 24H ff6144 v2048 decoder over "
           "EnCodec tokens; text-conditioning frontend stubbed")

INTERNVL2_26B = ArchConfig(
    name="internvl2-26b", family="vlm", d_model=6144, vocab=92_553,
    groups=(Group((dense_layer(6144, 48, 8, 16_384),), 48),),
    rope_theta=1e6, sharding_policy="tp",  # §Perf: 40GB fits TP-16
    frontend=FrontendCfg(kind="vision", n_embeds=1024, embed_dim=3200,
                         source="InternViT-6B patch embeddings (stub)"),
    source="[arXiv:2404.16821] InternLM2 backbone: 48L d6144 48H(kv8) "
           "ff16384 v92553; InternViT-6B stubbed, projector trained")


# ---------------------------------------------------------------------------
# paper's own models (OPT family) — used by the dtrain experiments
# ---------------------------------------------------------------------------

def _opt(name: str, n_layers: int, d: int, h: int, ff: int) -> ArchConfig:
    return uniform_dense(
        name, n_layers=n_layers, d_model=d, n_heads=h, n_kv=h, d_ff=ff,
        vocab=50_272, qkv_bias=True, gated_mlp=False, act="relu",
        norm="layernorm", pos="learned", tie_embeddings=True,
        source="[arXiv:2205.01068] OPT family (paper's experiments)")


OPT_125M = _opt("opt-125m", 12, 768, 12, 3072)
OPT_1_3B = _opt("opt-1.3b", 24, 2048, 32, 8192)
OPT_2_7B = _opt("opt-2.7b", 32, 2560, 32, 10_240)


# ---------------------------------------------------------------------------
# registry + reduced variants
# ---------------------------------------------------------------------------

REGISTRY: dict[str, ArchConfig] = {
    c.name: c for c in [
        JAMBA_15_LARGE, QWEN15_05B, TINYLLAMA_11B, QWEN2_72B, KIMI_K2,
        MUSICGEN_MEDIUM, INTERNVL2_26B, FALCON_MAMBA_7B, GEMMA3_1B,
        DEEPSEEK_V2, OPT_125M, OPT_1_3B, OPT_2_7B,
    ]
}

ASSIGNED = [
    "jamba-1.5-large-398b", "qwen1.5-0.5b", "tinyllama-1.1b", "qwen2-72b",
    "kimi-k2-1t-a32b", "musicgen-medium", "internvl2-26b", "falcon-mamba-7b",
    "gemma3-1b", "deepseek-v2-236b",
]


def get(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}' (have {sorted(REGISTRY)})")
    return REGISTRY[name]


def _shrink_attn(a: AttnCfg | None, d: int) -> AttnCfg | None:
    if a is None:
        return None
    h = max(2, min(a.n_heads, 4))
    kv = 1 if a.n_kv_heads < a.n_heads else h
    hd = max(8, d // h)
    return AttnCfg(h, kv, hd, a.qkv_bias,
                   None if a.window is None else 16,
                   q_lora=32 if a.q_lora else 0,
                   kv_lora=16 if a.kv_lora else 0,
                   rope_head_dim=8 if a.rope_head_dim else 0,
                   v_head_dim=hd if a.v_head_dim else 0)


def _shrink_slot(s: LayerCfg, d: int) -> LayerCfg:
    mam = None
    if s.mamba is not None:
        mam = MambaCfg(d_inner=2 * d, d_state=4, d_conv=4, dt_rank=8, chunk=8)
    moe = None
    if s.moe is not None:
        # capacity_factor 8: drop-free at smoke scale so prefill/decode paths
        # are exactly consistent with the full forward (capacity token
        # dropping is legitimately order-dependent at production scale)
        moe = MoECfg(n_experts=4, top_k=min(2, s.moe.top_k), d_ff_expert=2 * d,
                     n_shared=min(1, s.moe.n_shared),
                     capacity_factor=8.0, router_aux=s.moe.router_aux)
    return LayerCfg(mixer=s.mixer, attn=_shrink_attn(s.attn, d), mamba=mam,
                    ffn=s.ffn, d_ff=2 * d if s.ffn == "dense" else 0, moe=moe)


def reduced(cfg: ArchConfig, d_model: int = 64, max_slots: int = 2) -> ArchConfig:
    """≤2-layer, tiny-width smoke variant preserving the family's layer mix.

    For pattern archs we keep the two most *diverse* slots of the first group
    (e.g. Jamba: one attention slot + one mamba+MoE slot).
    """
    slots = [s for g in cfg.groups for s in g.slots]
    if len(slots) > max_slots:
        # maximize diversity: prefer distinct (mixer, ffn) combos
        seen: dict[tuple, LayerCfg] = {}
        for s in slots:
            seen.setdefault((s.mixer, s.ffn), s)
        slots = list(seen.values())[:max_slots]
    slots = [_shrink_slot(s, d_model) for s in slots]

    fe = None
    if cfg.frontend is not None:
        fe = dataclasses.replace(cfg.frontend, n_embeds=8, embed_dim=32)
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", d_model=d_model, vocab=256,
        groups=(Group(tuple(slots), 1),), frontend=fe, max_seq=128,
        sharding_policy="tp")
