"""Architecture / shape configuration dataclasses.

An ``ArchConfig`` describes a decoder stack as a list of *groups*; each group
is a repeating *period* of layer slots that is lax.scan'ed over its ``reps``
(keeping HLO size depth-independent).  E.g.

* dense 80L           -> one group, 1 slot, 80 reps
* Jamba (1:7, MoE/2)  -> one group, 8 slots (1 attn + 7 mamba, MoE on odd), 9 reps
* Gemma-3 (5 local:1 global), 26L -> group(5 local + 1 global) × 4  +  group(local) × 2
"""
from __future__ import annotations

import dataclasses

#: Legal values of the ``kernel_backend`` knob (SubCGEConfig / DTrainConfig /
#: PodConfig).  ``auto`` resolves once per process — Pallas on TPU, the
#: pure-jnp oracles elsewhere; ``interpret`` runs the real Pallas lowerings
#: through the interpreter (CI on CPU); ``jnp``/``pallas`` force a path.
#: Dispatch lives in ``repro.kernels.ops``; DESIGN.md §7 has the contract.
KERNEL_BACKENDS = ("auto", "pallas", "interpret", "jnp")


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: int | None = None          # None = global attention
    # MLA (DeepSeek-V2): active iff kv_lora > 0
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0             # decoupled RoPE dims (MLA)
    v_head_dim: int = 0                # MLA value head dim (0 -> head_dim)

    @property
    def is_mla(self) -> bool:
        return self.kv_lora > 0


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0                   # 0 -> ceil(d_model/16)
    chunk: int = 256                   # associative-scan chunking (memory)


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0                  # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux: float = 0.0            # load-balance aux loss coefficient


@dataclasses.dataclass(frozen=True)
class LayerCfg:
    mixer: str = "attn"                # "attn" | "mamba" | "none"
    attn: AttnCfg | None = None
    mamba: MambaCfg | None = None
    ffn: str = "dense"                 # "dense" | "moe" | "none"
    d_ff: int = 0
    moe: MoECfg | None = None


@dataclasses.dataclass(frozen=True)
class Group:
    slots: tuple[LayerCfg, ...]
    reps: int


@dataclasses.dataclass(frozen=True)
class FrontendCfg:
    """Stubbed modality frontend (the one allowed carve-out): input_specs()
    supplies precomputed frame/patch embeddings; we own only the projector."""
    kind: str                          # "vision" | "audio_cond"
    n_embeds: int                      # patches / conditioning frames
    embed_dim: int                     # pre-projector dim (e.g. ViT width)
    source: str = ""


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab: int
    groups: tuple[Group, ...]
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    act: str = "silu"                  # silu | gelu | relu
    gated_mlp: bool = True
    pos: str = "rope"                  # rope | learned | sinusoidal | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    max_seq: int = 131_072
    frontend: FrontendCfg | None = None
    sharding_policy: str = "tp"        # tp | fsdp_tp | ep
    # §Perf: all-gather fsdp-sharded expert weights at use instead of
    # psumming expert activation buffers (see models/perturb.expert_dense)
    moe_gather_weights: bool = False
    # §Perf: pin the residual stream's d_model axis to replicated.  Under
    # fsdp_tp the embedding output inherits "embed"->data sharding and every
    # downstream contraction then psums activations over data; this
    # constraint makes weights (not activations) pay the fsdp gather.
    residual_replicated: bool = False
    # long_500k handling: "native" (sub-quadratic already) or "sliding_window"
    # (explicit variant for full-attention archs; see DESIGN.md §5)
    long_context_mode: str = "sliding_window"
    sliding_window_size: int = 4096
    source: str = ""                   # citation [arXiv:... / hf:...]

    @property
    def n_layers(self) -> int:
        return sum(len(g.slots) * g.reps for g in self.groups)

    def layer_cfgs(self) -> list[LayerCfg]:
        out: list[LayerCfg] = []
        for g in self.groups:
            out.extend(list(g.slots) * g.reps)
        return out

    def with_sliding_window(self, window: int) -> "ArchConfig":
        """Long-context variant: clamp every global-attention slot to a
        sliding window (ring-buffer cache).  Used by long_500k for
        full-attention archs."""
        def clamp(slot: LayerCfg) -> LayerCfg:
            if slot.mixer != "attn" or slot.attn is None:
                return slot
            w = slot.attn.window
            new_w = window if w is None else min(w, window)
            return dataclasses.replace(slot, attn=dataclasses.replace(slot.attn, window=new_w))

        groups = tuple(dataclasses.replace(g, slots=tuple(clamp(s) for s in g.slots))
                       for g in self.groups)
        return dataclasses.replace(self, groups=groups,
                                   name=self.name + "+sw" + str(window))

    def for_shape(self, shape: "InputShape") -> "ArchConfig":
        """Arch variant actually lowered for a given input shape."""
        if (shape.kind == "decode" and shape.seq > 100_000
                and self.long_context_mode == "sliding_window"):
            return self.with_sliding_window(self.sliding_window_size)
        return self


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Declarative churn spec for decentralized runs (DESIGN.md §6).

    Plain data (serializable, hashable) so sweeps and benchmark tables can
    carry churn settings; ``repro.topology.dynamic.ChurnSchedule.from_config``
    resolves it into the concrete event script.  ``leave_at``/``rejoin_at``
    double as down/up (link_flap) and at/heal (partition) steps.
    """
    kind: str = "leave_rejoin"         # leave_rejoin | link_flap | partition | random
    nodes: tuple[int, ...] = ()        # leave_rejoin
    leave_at: int = 0
    rejoin_at: int = 0
    edges: tuple[tuple[int, int], ...] = ()          # link_flap
    groups: tuple[tuple[int, ...], ...] = ()         # partition
    n: int = 0                         # random: client count
    steps: int = 0                     # random: horizon
    rate: float = 0.0                  # random: per-step leave probability
    seed: int = 0
    outage: tuple[int, int] = (5, 15)  # random: offline duration range
    max_concurrent: int = 1            # random: max simultaneous departures


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# -- small builders ----------------------------------------------------------

def dense_layer(d_model: int, n_heads: int, n_kv: int, d_ff: int,
                head_dim: int | None = None, qkv_bias: bool = False,
                window: int | None = None) -> LayerCfg:
    hd = head_dim if head_dim is not None else d_model // n_heads
    return LayerCfg(mixer="attn",
                    attn=AttnCfg(n_heads, n_kv, hd, qkv_bias, window),
                    ffn="dense", d_ff=d_ff)


def uniform_dense(name: str, *, n_layers: int, d_model: int, n_heads: int,
                  n_kv: int, d_ff: int, vocab: int, head_dim: int | None = None,
                  qkv_bias: bool = False, **kw) -> ArchConfig:
    slot = dense_layer(d_model, n_heads, n_kv, d_ff, head_dim, qkv_bias)
    return ArchConfig(name=name, family="dense", d_model=d_model, vocab=vocab,
                      groups=(Group((slot,), n_layers),), **kw)
