"""Optimizers and schedules.

The paper trains with plain constant-LR SGD, no momentum, no weight decay
(App. B.2) — both for the ZO methods (the coefficient η·α/n *is* the SGD
step) and the FO baselines.  Momentum-SGD and Adam are provided for the FO
baselines' ablations; ZO state stays empty by construction (a structural
memory advantage recorded in the roofline tables).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: Any | None


def sgd_init(params: Any, momentum: float = 0.0) -> SGDState:
    if momentum == 0.0:
        return SGDState(None)
    return SGDState(jax.tree.map(jnp.zeros_like, params))


def sgd_update(params: Any, grads: Any, state: SGDState, lr: float,
               momentum: float = 0.0):
    if momentum == 0.0 or state.momentum is None:
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state
    buf = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                       state.momentum, grads)
    new = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, buf)
    return new, SGDState(buf)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adam_init(params: Any) -> AdamState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamState(z, jax.tree.map(jnp.copy, z), jnp.zeros((), jnp.int32))


def adam_update(params: Any, grads: Any, state: AdamState, lr: float,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    c = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** c), mu)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** c), nu)
    new = jax.tree.map(lambda p, m, v: (p - lr * m / (jnp.sqrt(v) + eps)).astype(p.dtype),
                       params, mh, vh)
    return new, AdamState(mu, nu, c)


def constant_lr(lr: float) -> Callable[[int], float]:
    return lambda step: lr


def cosine_lr(lr: float, total: int, warmup: int = 0) -> Callable[[int], float]:
    def fn(step: int) -> float:
        if warmup and step < warmup:
            return lr * (step + 1) / warmup
        t = (step - warmup) / max(1, total - warmup)
        return 0.5 * lr * (1.0 + float(jnp.cos(jnp.pi * min(t, 1.0))))
    return fn
