"""Continuous-batching decode server over live seed-reconstructed weights.

One :class:`DecodeServer` owns a full parameter replica, a paged KV pool,
and a :class:`~repro.serve.scheduler.Scheduler`.  Each :meth:`step` is one
decode-step boundary:

    1. fold   — buffered flood messages fold into θ (LiveUpdateBridge)
    2. admit  — queued requests claim slots + pages; one jitted prefill
                per distinct (batch, prompt-length) scatters their KV
    3. decode — one jitted paged-decode dispatch at the current page
                bucket emits a token for every active slot
    4. evict  — finished slots free their pages back to the queue

Compiled programs are cached per shape key — (Bg, T) for prefill, bucket
for decode — so a long-running server converges to a handful of traces.
No buffer donation anywhere: simulated servers may share a params tree
(and on CPU donation is a no-op with warnings), and the live-update parity
oracle compares against the undonated monolithic path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.serve.bridge import LiveUpdateBridge
from repro.serve.scheduler import Request, Scheduler, ServeConfig


class DecodeServer:
    """Continuous-batching token server for one (possibly churning) node."""

    def __init__(self, cfg, params, serve: ServeConfig, *, mesh=None,
                 pod=None, bridge: LiveUpdateBridge | None = None):
        tf.check_paged_support(cfg)
        self.cfg = cfg
        self.serve = serve
        self.mesh = mesh if mesh is not None else make_host_mesh(1, 1)
        self.pod = pod if pod is not None else steplib.PodConfig(
            param_dtype=serve.param_dtype)
        self.bridge = bridge
        self.params = params
        with self.mesh:
            self.pool = tf.init_paged_pool(cfg, serve.n_pages,
                                           serve.page_size, serve.param_dtype)
        self.sched = Scheduler(serve)
        self.results: dict[int, list[int]] = {}
        self._prefill_fns: dict[tuple[int, int], object] = {}
        self._decode_fns: dict[int, object] = {}
        self.n_steps = 0
        self.n_prefills = 0
        self.n_decodes = 0
        self.n_suspends = 0

    # -- request intake -------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.rid in self.results:
            raise ValueError(f"duplicate request id {req.rid}")
        self.results[req.rid] = []
        self.sched.submit(req)

    # -- compiled-program cache -----------------------------------------------

    def _prefill_fn(self, Bg: int, T: int):
        fn = self._prefill_fns.get((Bg, T))
        if fn is None:
            shape = InputShape("serve", T, Bg, "prefill")
            step, _, in_sh, out_sh = steplib.build_paged_prefill_step(
                self.cfg, shape, self.mesh, self.pod,
                page_size=self.serve.page_size,
                pages_per_req=self.serve.pages_per_req,
                n_pages=self.serve.n_pages)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            self._prefill_fns[(Bg, T)] = fn
        return fn

    def _decode_fn(self, bucket: int):
        fn = self._decode_fns.get(bucket)
        if fn is None:
            shape = InputShape("serve", bucket * self.serve.page_size,
                               self.serve.max_batch, "decode")
            step, _, in_sh, out_sh = steplib.build_paged_decode_step(
                self.cfg, shape, self.mesh, self.pod,
                page_size=self.serve.page_size, pages_per_req=bucket,
                n_pages=self.serve.n_pages)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            self._decode_fns[bucket] = fn
        return fn

    # -- sampling -------------------------------------------------------------

    def _sample(self, logits_row, rid: int, emit_pos: int) -> int:
        """Token for one slot's logits.  ``emit_pos`` is the absolute
        position the sampled token will occupy — (rid, emit_pos) keys the
        PRNG stream, so a run is deterministic and churn-replayable."""
        if self.serve.sampling == "greedy":
            return int(jnp.argmax(logits_row))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.serve.sample_seed),
                               rid), emit_pos)
        return int(jax.random.categorical(
            key, logits_row / self.serve.temperature))

    # -- one decode-step boundary ---------------------------------------------

    def step(self) -> None:
        if self.sched.done:
            return
        self.n_steps += 1
        if self.bridge is not None and self.bridge.pending:
            self.params = self.bridge.fold(self.params)
        admitted = self.sched.admit()
        groups: dict[int, list[tuple[int, Request]]] = {}
        for slot, req in admitted:
            groups.setdefault(len(req.prompt), []).append((slot, req))
        for T in sorted(groups):
            self._prefill_group(T, groups[T])
        if self.sched.active_slots():
            self._decode_once()

    def _prefill_group(self, T: int, group: list[tuple[int, Request]]):
        Bg = len(group)
        tokens = np.stack([r.prompt for _, r in group])
        table = np.stack([self.sched.alloc.table[s] for s, _ in group])
        fn = self._prefill_fn(Bg, T)
        with self.mesh:
            last, self.pool = fn(self.params, self.pool,
                                 jnp.asarray(tokens), jnp.asarray(table))
        self.n_prefills += 1
        for i, (slot, req) in enumerate(group):
            # prefill emits the token at position len(prompt) == slot.pos
            tok = self._sample(last[i], req.rid, self.sched.slots[slot].pos)
            self.results[req.rid].append(tok)
            self.sched.record_emit(slot, tok)

    def _decode_once(self):
        bucket = self.sched.decode_bucket()
        tokens, pos, table = self.sched.decode_inputs()
        fn = self._decode_fn(bucket)
        with self.mesh:
            logits, self.pool = fn(self.params, self.pool,
                                   jnp.asarray(tokens), jnp.asarray(table),
                                   jnp.asarray(pos))
        self.n_decodes += 1
        for slot in self.sched.active_slots():
            s = self.sched.slots[slot]
            # the decode wrote position s.pos; its token lands at s.pos + 1
            tok = self._sample(logits[slot], s.req.rid, s.pos + 1)
            self.results[s.req.rid].append(tok)
            if not self.sched.record_emit(slot, tok):
                self.sched.advance(slot)

    # -- churn ----------------------------------------------------------------

    def suspend(self) -> int:
        """Node leaves mid-decode: every in-flight request is captured from
        its slot and page table as a resume request — prompt = tokens
        written so far, budget = remaining — and re-queued at the FRONT in
        slot order; its pages return to the free list.  On rejoin the
        normal admit path re-reserves pages and a re-prefill of the
        accumulated sequence resumes decode (the weights catch up
        separately, through anti-entropy into the bridge)."""
        n = 0
        for slot in reversed(self.sched.active_slots()):
            s = self.sched.slots[slot]
            emitted = s.req.max_new - s.remaining
            out = self.results[s.req.rid]
            toks = np.asarray(out[len(out) - emitted:], np.int32)
            seq = np.concatenate([s.req.prompt, toks]) if emitted \
                else s.req.prompt
            self.sched.release_slot(slot)
            self.sched.queue.appendleft(
                Request(rid=s.req.rid, prompt=seq, max_new=s.remaining))
            n += 1
        self.n_suspends += n
        return n

    # -- driver ---------------------------------------------------------------

    def run(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        steps = 0
        while not self.sched.done:
            if steps >= max_steps:
                raise RuntimeError(
                    f"serve loop still busy after {max_steps} steps "
                    f"({len(self.sched.queue)} queued, "
                    f"{len(self.sched.active_slots())} active)")
            self.step()
            steps += 1
        return self.results

    def stats(self) -> dict:
        out = {"steps": self.n_steps, "prefills": self.n_prefills,
               "decodes": self.n_decodes, "suspends": self.n_suspends,
               "evicted": self.sched.n_evicted,
               "queued": len(self.sched.queue),
               "active": len(self.sched.active_slots()),
               "emitted": sum(len(v) for v in self.results.values())}
        if self.bridge is not None:
            out["bridge"] = self.bridge.stats()
        return out
