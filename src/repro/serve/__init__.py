"""Churn-tolerant continuous-batching decode over live seed-reconstructed
weights (DESIGN.md §10)."""
from repro.serve.bridge import LiveUpdateBridge
from repro.serve.paged_cache import PageAllocator, bucket_pages, pages_needed
from repro.serve.scheduler import (SAMPLING_KINDS, Request, Scheduler,
                                   ServeConfig)
from repro.serve.server import DecodeServer
from repro.serve.sim import ServeSwarmSim

__all__ = [
    "LiveUpdateBridge",
    "PageAllocator", "bucket_pages", "pages_needed",
    "SAMPLING_KINDS", "Request", "Scheduler", "ServeConfig",
    "DecodeServer",
    "ServeSwarmSim",
]
