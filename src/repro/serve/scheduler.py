"""Continuous-batching request scheduler (DESIGN.md §10).

Pure host-side logic — no device work, no clocks — so admission, eviction
and page accounting are unit-testable and a serve run is a deterministic
function of its request script.  The :class:`~repro.serve.server.DecodeServer`
drives one :class:`Scheduler` and turns its decisions into jitted prefill /
decode dispatches.

Policy (deliberately simple and fully pinned by tests):

* FIFO admission — requests admit in submission order into the lowest free
  slot, as long as the head of the queue can reserve its full page budget.
  The queue never reorders (no starvation, no nondeterminism).
* Eviction on completion — a slot frees its pages the step its request
  emits its last token; the pages immediately become available to the
  queue (free-list reuse).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.serve.paged_cache import PageAllocator, bucket_pages, pages_needed

SAMPLING_KINDS = ("greedy", "temperature")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of one serving node (every field is consumed — SF004)."""
    max_batch: int = 8           # continuous-batching decode width (slots)
    page_size: int = 16          # tokens per KV page
    n_pages: int = 64            # pool size (excluding the dump page)
    max_seq: int = 128           # per-request position cap (prompt + new)
    sampling: str = "greedy"     # "greedy" | "temperature"
    temperature: float = 1.0     # temperature-sampling divisor
    sample_seed: int = 0         # PRNG root for temperature sampling
    param_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.sampling not in SAMPLING_KINDS:
            raise ValueError(f"sampling must be one of {SAMPLING_KINDS}, "
                             f"got '{self.sampling}'")
        if self.max_seq % self.page_size != 0:
            raise ValueError(f"max_seq ({self.max_seq}) must be a multiple "
                             f"of page_size ({self.page_size})")
        if self.sampling == "temperature" and self.temperature <= 0:
            raise ValueError("temperature must be > 0")

    @property
    def pages_per_req(self) -> int:
        return self.max_seq // self.page_size


@dataclasses.dataclass
class Request:
    """One decode request.  ``rid`` must be unique per server."""
    rid: int
    prompt: np.ndarray            # (L,) int32 token ids
    max_new: int                  # tokens to emit (>= 1; first from prefill)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")


@dataclasses.dataclass
class _Slot:
    req: Request
    pos: int          # absolute position of the next token to be written
    remaining: int    # tokens still to emit
    last_tok: int     # last emitted token (next decode input)


class Scheduler:
    """Slot + page bookkeeping for one serving node."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.alloc = PageAllocator(cfg.n_pages, cfg.page_size, cfg.max_batch,
                                   cfg.pages_per_req)
        self.slots: list[_Slot | None] = [None] * cfg.max_batch
        self.queue: deque[Request] = deque()
        self.n_evicted = 0

    # -- submission / admission ---------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + max_new "
                f"({req.max_new}) exceeds max_seq ({self.cfg.max_seq})")
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """FIFO-admit queued requests into free slots while the head can
        reserve its full page budget.  Returns [(slot, request)] admitted."""
        admitted = []
        while self.queue:
            req = self.queue[0]
            need = pages_needed(len(req.prompt) + req.max_new,
                                self.cfg.page_size)
            slot = next((i for i, s in enumerate(self.slots) if s is None),
                        None)
            if slot is None or not self.alloc.can_alloc(need):
                break
            self.queue.popleft()
            self.alloc.alloc(slot, need)
            self.slots[slot] = _Slot(req=req, pos=len(req.prompt),
                                     remaining=req.max_new, last_tok=-1)
            admitted.append((slot, req))
        return admitted

    # -- decode-step views ---------------------------------------------------

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def decode_bucket(self) -> int:
        """Pages the decode gather must cover for the furthest-along active
        request (its write position pos is attended inclusively)."""
        need = max(pages_needed(s.pos + 1, self.cfg.page_size)
                   for s in self.slots if s is not None)
        return bucket_pages(need, self.cfg.pages_per_req)

    def decode_inputs(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(tokens (B,1), pos (B,), table (B, bucket)) for one decode step.
        Inactive slots feed token 0 at position 0 through dump-page table
        rows — their lane computes garbage nobody reads or stores."""
        B = self.cfg.max_batch
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i, 0] = s.last_tok
                pos[i] = s.pos
        table = self.alloc.table[:, :self.decode_bucket()]
        return tokens, pos, table

    # -- progression ---------------------------------------------------------

    def record_emit(self, slot: int, tok: int) -> bool:
        """Record one emitted token for ``slot``; evicts (and frees pages)
        when the request completes.  Returns True if the slot finished."""
        s = self.slots[slot]
        s.last_tok = tok
        s.remaining -= 1
        if s.remaining == 0:
            self.alloc.release(slot)
            self.slots[slot] = None
            self.n_evicted += 1
            return True
        return False

    def advance(self, slot: int) -> None:
        self.slots[slot].pos += 1

    def release_slot(self, slot: int) -> None:
        """Free a slot without completing it (suspension on node leave)."""
        self.alloc.release(slot)
        self.slots[slot] = None

    @property
    def done(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
