"""Churn-tolerant serving swarm on the repro.sim virtual clock (DESIGN.md §10).

Trainer nodes flood one SubCGE message each per virtual train step through a
real :class:`~repro.core.transport.FloodTransport` (bytes charged to its
CommLedger); server nodes run :class:`~repro.serve.server.DecodeServer`
steps at their own cadence, folding whatever the flood has delivered at
each decode-step boundary.  A step-indexed
:class:`~repro.topology.dynamic.ChurnSchedule` (mapped onto virtual time by
``train_period``) takes servers offline mid-decode: *leave* suspends their
in-flight requests back onto the queue, *join* re-admits them through the
normal admission path — pages re-reserved from the free list, KV rebuilt by
re-prefill — while the bridge catches the weights up from the transport's
anti-entropy.

No wall clocks anywhere (SF001/SF002): a run is a pure function of
(configs, request script, churn schedule), so running it twice yields
bitwise-identical token streams and byte ledgers — the replay oracle
``tests/test_serve.py`` pins.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.messages import Message
from repro.core.seeds import client_seed
from repro.core.transport import FloodTransport
from repro.models import params as plib
from repro.models import transformer as tf
from repro.serve.bridge import LiveUpdateBridge
from repro.serve.scheduler import Request, ServeConfig
from repro.serve.server import DecodeServer
from repro.sim.events import RANK_CHURN, EventQueue, churn_event, step_event
from repro.topology import graphs

#: ``client`` id carried by the collective trainer-tick STEP event.
TRAINER_TICK = -1


class ServeSwarmSim:
    """Trainers flood; servers decode under live updates; churn replays."""

    def __init__(self, cfg, scfg, serve_cfg: ServeConfig, *,
                 n_trainers: int = 2, n_servers: int = 1,
                 train_steps: int = 4, global_seed: int = 0,
                 coef_fn: Callable[[int, int], float] | None = None,
                 churn=None, train_period: float = 1.0,
                 serve_period: float = 0.25, graph=None,
                 flood_k: int | None = None, max_events: int = 100_000):
        self.cfg = cfg
        self.n_trainers = n_trainers
        self.n = n_trainers + n_servers
        self.train_steps = train_steps
        self.global_seed = global_seed
        self.coef_fn = coef_fn if coef_fn is not None \
            else (lambda t, i: 0.01 / (1 + t + i))
        self.churn = churn
        self.train_period = train_period
        self.serve_period = serve_period
        self.max_events = max_events
        g = graph if graph is not None else graphs.ring(self.n)
        self.transport = FloodTransport(g, flood_k=flood_k)
        params = plib.init_params(tf.arch_spec(cfg), 0, serve_cfg.param_dtype)
        self.servers: dict[int, DecodeServer] = {}
        for node in range(n_trainers, self.n):
            bridge = LiveUpdateBridge(cfg, scfg, global_seed, node)
            self.servers[node] = DecodeServer(cfg, params, serve_cfg,
                                              bridge=bridge)
        self.online = {node: True for node in self.servers}
        self._gen = {node: 0 for node in self.servers}
        if churn is not None:
            bad = sorted({n for ev in churn.events for n in ev.nodes
                          if n not in self.servers})
            if bad:
                raise ValueError(f"churn may only target server nodes "
                                 f"{sorted(self.servers)}, got {bad}")

    def submit(self, node: int, req: Request) -> None:
        self.servers[node].submit(req)

    # -- event handlers -------------------------------------------------------

    def _trainer_tick(self, t: int) -> None:
        """One collective train step: every trainer floods its (seed, coef,
        step) message; every online server's bridge buffers its inbox row
        (anti-entropy catch-up from an earlier rejoin rides the same padded
        matrices — FloodTransport prepends its pending payload)."""
        msgs = [(i, Message(seed=int(client_seed(self.global_seed, t, i)),
                            coef=float(self.coef_fn(t, i)), origin=i, step=t))
                for i in range(self.n_trainers)]
        active = np.array([i < self.n_trainers or self.online[i]
                           for i in range(self.n)])
        inbox = self.transport.exchange(msgs, t, active)
        for node, srv in self.servers.items():
            if self.online[node]:
                srv.bridge.ingest(inbox)

    def _server_step(self, ev, q: EventQueue) -> None:
        node = ev.client
        if ev.client_gen != self._gen[node] or not self.online[node]:
            return                      # cancelled by a later churn event
        srv = self.servers[node]
        srv.step()
        if not srv.sched.done:
            q.push(step_event(ev.time + self.serve_period, node,
                              ev.step + 1, self._gen[node]))

    def _handle_churn(self, ev, q: EventQueue) -> None:
        evs = self.churn.events_at(ev.step)
        for e in evs:
            if e.kind == "leave":
                for node in e.nodes:
                    if self.online[node]:
                        self.servers[node].suspend()
                        self.online[node] = False
                        self._gen[node] += 1
        self.transport.apply_churn(evs)
        for e in evs:
            if e.kind == "join":
                for node in e.nodes:
                    if not self.online[node]:
                        self.online[node] = True
                        self._gen[node] += 1
                        q.push(step_event(ev.time + self.serve_period, node,
                                          0, self._gen[node]))

    # -- driver ---------------------------------------------------------------

    def run(self) -> dict:
        q = EventQueue()
        for t in range(self.train_steps):
            q.push(step_event(t * self.train_period, TRAINER_TICK, t))
        for node in self.servers:
            q.push(step_event(self.serve_period, node, 0, self._gen[node]))
        if self.churn is not None:
            for s in sorted({ev.step for ev in self.churn.events}):
                q.push(churn_event(s * self.train_period, s))

        n_events = 0
        while q:
            ev = q.pop()
            n_events += 1
            if n_events > self.max_events:
                raise RuntimeError(f"serve sim exceeded {self.max_events} "
                                   f"events — runaway schedule?")
            if ev.rank == RANK_CHURN:
                self._handle_churn(ev, q)
            elif ev.client == TRAINER_TICK:
                self._trainer_tick(ev.step)
            else:
                self._server_step(ev, q)

        stuck = [node for node, srv in self.servers.items()
                 if not srv.sched.done]
        if stuck:
            raise RuntimeError(f"servers {stuck} ended offline with "
                               f"unfinished requests — extend the schedule "
                               f"or rejoin them before the run drains")

        tokens: dict[int, list[int]] = {}
        for node, srv in self.servers.items():
            for rid, toks in srv.results.items():
                if rid in tokens:
                    raise ValueError(f"request id {rid} served by two nodes")
                tokens[rid] = toks
        return {"tokens": tokens,
                "ledger": dataclasses.asdict(self.transport.ledger),
                "servers": {node: srv.stats()
                            for node, srv in self.servers.items()}}
