"""Host-side paged KV-cache bookkeeping (DESIGN.md §10).

The device side is a per-attention-slot page pool
(:func:`repro.models.transformer.init_paged_pool`) of ``n_pages + 1``
physical pages; this module owns the *logical* side: which physical pages
each request slot holds, the free list, and the page-bucket policy that
bounds jit retraces of the decode step.

Allocation is reservation-based: a request reserves every page its full
lifetime (prompt + max_new positions) needs at admission, so decode can
never OOM mid-flight and the admission decision is a pure function of the
free-list length — deterministic, replayable.  The LAST physical page
(index ``n_pages``) is the dump page: unreserved table entries point at it,
inactive decode slots scatter into it, and no live request ever gathers it
with nonzero attention probability.
"""
from __future__ import annotations

import numpy as np

from repro.core.messages import pad_pow2


def pages_needed(n_positions: int, page_size: int) -> int:
    return -(-n_positions // page_size)


def bucket_pages(needed: int, pages_per_req: int) -> int:
    """Gather-width bucket (in pages) for the longest active request:
    next power of two, capped at the per-request maximum.  One decode trace
    exists per bucket, so a serve run compiles O(log pages_per_req) decode
    programs instead of one per sequence length."""
    if needed <= 0:
        needed = 1
    return min(pad_pow2(needed, minimum=1), pages_per_req)


class PageAllocator:
    """LIFO free-list allocator over the physical page pool.

    ``table`` is the dense (max_batch, pages_per_req) int32 page table the
    decode step consumes directly (sliced to the active bucket width);
    unreserved entries hold the dump page id.
    """

    def __init__(self, n_pages: int, page_size: int, max_batch: int,
                 pages_per_req: int):
        if n_pages < pages_per_req:
            raise ValueError(f"pool of {n_pages} pages cannot hold even one "
                             f"full request ({pages_per_req} pages)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.pages_per_req = pages_per_req
        self.dump = n_pages
        # pop() yields lowest ids first; released pages are re-pushed so the
        # next alloc reuses them in the same order (pinned by test_serve)
        self._free = list(range(n_pages - 1, -1, -1))
        self.table = np.full((max_batch, pages_per_req), self.dump, np.int32)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def can_alloc(self, k: int) -> bool:
        return k <= self.pages_per_req and k <= len(self._free)

    def alloc(self, slot: int, k: int) -> list[int]:
        """Reserve ``k`` pages for request slot ``slot``; returns their ids."""
        if not self.can_alloc(k):
            raise ValueError(f"cannot allocate {k} pages "
                             f"({len(self._free)} free, "
                             f"{self.pages_per_req} per-request max)")
        if (self.table[slot] != self.dump).any():
            raise ValueError(f"slot {slot} already holds pages")
        pages = [self._free.pop() for _ in range(k)]
        self.table[slot, :k] = pages
        return pages

    def release(self, slot: int) -> list[int]:
        """Return slot ``slot``'s pages to the free list (eviction)."""
        pages = [int(p) for p in self.table[slot] if p != self.dump]
        self._free.extend(reversed(pages))
        self.table[slot] = self.dump
        return pages
