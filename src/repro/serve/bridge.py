"""Live-update bridge: flood inbox -> resident serving params (DESIGN.md §10).

A serving node holds a full replica of θ and subscribes to the same
SeedFlood overlay the trainers flood over.  Each step's
:class:`~repro.core.transport.FloodInbox` row for the node is buffered
here; at the next decode-step boundary the whole buffer folds into θ in
one jitted dispatch through :func:`repro.core.subcge.apply_messages_epoch`
— the epoch-grouped fold, so messages whose sender step crosses a
τ-refresh boundary are applied under the SENDER's subspace (PR 2's rule).
Because an update is (seed, coef, step) triples, folding K messages costs
one r×r scatter + one U A Vᵀ per weight — no tensors ever ship, which is
what makes fine-tune-while-serve cheap under SeedFlood.

Byte accounting stays in the Transport layer (SF005): the bridge only ever
consumes inbox rows the transport already charged to its CommLedger.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import subcge
from repro.core.messages import pad_pow2
from repro.core.subcge import SubCGEConfig
from repro.models import params as plib
from repro.models import transformer as tf

#: Padding triple for partially filled fold batches: coef 0.0 is an exact
#: no-op on every leaf kind and step -1 matches no epoch slot.
_PAD = (np.uint32(0), np.float32(0.0), np.int32(-1))


class LiveUpdateBridge:
    """Buffers SubCGE flood messages for one serving node and folds them."""

    def __init__(self, arch_cfg, scfg: SubCGEConfig, global_seed: int,
                 node: int):
        self.meta = plib.subcge_meta(tf.arch_spec(arch_cfg))
        self.scfg = scfg
        self.global_seed = global_seed
        self.node = node
        self._seeds: list[int] = []
        self._coefs: list[float] = []
        self._steps: list[int] = []
        self._fold_fns: dict[tuple[int, int], Any] = {}
        self.messages_folded = 0
        self.n_folds = 0

    # -- ingest ---------------------------------------------------------------

    def ingest(self, inbox) -> int:
        """Buffer this node's row of a FloodInbox; returns messages taken."""
        return self.ingest_arrays(inbox.seeds[self.node],
                                  inbox.coefs[self.node],
                                  inbox.steps[self.node])

    def ingest_arrays(self, seeds, coefs, steps) -> int:
        seeds = np.asarray(seeds).reshape(-1)
        coefs = np.asarray(coefs).reshape(-1)
        steps = np.asarray(steps).reshape(-1)
        live = steps >= 0                       # step -1 marks payload padding
        self._seeds.extend(np.uint32(seeds[live]).tolist())
        self._coefs.extend(np.float32(coefs[live]).tolist())
        self._steps.extend(np.int32(steps[live]).tolist())
        return int(live.sum())

    @property
    def pending(self) -> int:
        return len(self._seeds)

    # -- fold -----------------------------------------------------------------

    def _fold_fn(self, K: int, E: int):
        fn = self._fold_fns.get((K, E))
        if fn is None:
            def fold(params, seeds, coefs, steps, epochs):
                return subcge.apply_messages_epoch(
                    params, self.meta, self.scfg, self.global_seed,
                    seeds, coefs, steps, epochs)
            fn = jax.jit(fold)
            self._fold_fns[(K, E)] = fn
        return fn

    def fold(self, params):
        """Apply every buffered message to ``params`` (one jitted dispatch,
        pow2-padded so trace count stays bounded) and clear the buffer."""
        n = self.pending
        if n == 0:
            return params
        K = pad_pow2(n, minimum=1)
        seeds = np.full((K,), _PAD[0], np.uint32)
        coefs = np.full((K,), _PAD[1], np.float32)
        steps = np.full((K,), _PAD[2], np.int32)
        seeds[:n] = self._seeds
        coefs[:n] = self._coefs
        steps[:n] = self._steps
        epochs = subcge.epoch_slots(steps, self.scfg)
        fn = self._fold_fn(K, int(epochs.shape[0]))
        params = fn(params, jnp.asarray(seeds), jnp.asarray(coefs),
                    jnp.asarray(steps), jnp.asarray(epochs))
        self._seeds.clear()
        self._coefs.clear()
        self._steps.clear()
        self.messages_folded += n
        self.n_folds += 1
        return params

    def stats(self) -> dict:
        return {"messages_folded": self.messages_folded,
                "n_folds": self.n_folds, "pending": self.pending}
