"""Fused rank-1 perturbed forward machinery.

SeedFlood's perfect consensus means all n simulated clients share one θ; a
client's ZO forward differs only by its SubCGE perturbation, which is rank-1
per 2D leaf:  W_eff = W + s·u v^T  with  u = U[:, i], v = V[:, j].  Rather
than materializing per-client weights we fuse the rank-1 term into each
matmul:

    x (W + s u v^T)  =  x W  +  s · (x u) v^T          (O(T·(n+m)) extra)

``Bundle`` threads three parallel trees through the model — params, the
shared subspace (U/V, *not* per-client), and the per-client perturbation
(coords + dense Gaussians for non-2D leaves) — and exposes the handful of
parameterized ops the layers need.  pert=None gives the plain forward
(serving, FO baselines).

All of this vmaps over a client axis: params/subspace broadcast, pert mapped.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import seeds as seedlib
from repro.core import subcge
from repro.core.subcge import UV, LeafMeta, SubCGEConfig
from repro.kernels import ops as kops
from repro.models import params as plib


class Pert(NamedTuple):
    """One client's perturbation state (leaves carry NO client axis here —
    the client axis is introduced by vmap at the step level)."""
    ij: Any            # nested dict: IJ per matrix leaf
    zv: Any            # nested dict: dense Gaussian per non-frozen vector leaf
    scale: jax.Array   # ±ε (the dual forward flips the sign)

    def with_scale(self, s) -> "Pert":
        return Pert(self.ij, self.zv, jnp.asarray(s, jnp.float32))


def sample_pert(meta: dict[str, LeafMeta], cfg: SubCGEConfig, message_seed,
                scale) -> Pert:
    """RNG_S for one message seed, as *nested* trees mirroring the params."""
    coords = subcge.sample_coords(meta, cfg, message_seed)  # path -> IJ
    key = seedlib.message_key(message_seed)
    zv_flat: dict[str, jax.Array] = {}
    for path, m in sorted(meta.items()):
        if m.frozen or m.is_matrix:
            continue
        zv_flat[path] = seedlib.gaussian_like(seedlib.leaf_key(key, path),
                                              m.shape, jnp.float32)
    return Pert(plib.nest(coords), plib.nest(zv_flat),
                jnp.asarray(scale, jnp.float32))


def nest_subspace(sub_flat: dict[str, UV]) -> Any:
    return plib.nest(sub_flat)


def epoch_subspace(meta: dict[str, LeafMeta], cfg: SubCGEConfig, global_seed,
                   step) -> Any:
    """Nested shared (U, V) tree for the τ-epoch governing ``step`` (jit-safe).

    Sampling is epoch-parameterized *only* through the subspace: a message's
    coordinates and dense Gaussians (``sample_pert``) depend on the message
    seed alone, so reconstructing a sender's perturbation elsewhere needs
    exactly this subspace — regenerated at the SENDER's epoch — and nothing
    else.  The fused forward consumes the nested layout this returns.
    """
    return nest_subspace(subcge.subspace_at_step(meta, cfg, global_seed, step))


def _child(tree: Any, k: str):
    if tree is None or not isinstance(tree, dict):
        return None
    return tree.get(k)


def _mesh_active() -> bool:
    """True when a mesh context is available for sharding constraints
    (simulator / CPU smoke paths run mesh-less and skip them)."""
    try:
        from jax._src import mesh as _mesh_lib
        if not _mesh_lib.thread_resources.env.physical_mesh.empty:
            return True
        am = _mesh_lib.get_abstract_mesh()
        return am is not None and not am.empty
    except Exception:  # pragma: no cover - jax internals moved
        return False


class Bundle:
    """params + subspace + perturbation view over one nesting level.

    ``kb`` is the *resolved* kernel backend ("jnp" | "pallas" | "interpret")
    the perturbed matmuls dispatch through (DESIGN.md §7) — a plain Python
    string fixed at trace time, threaded from ``forward(kernel_backend=…)``.
    The unperturbed forward (serving, FO baselines, eval) never dispatches:
    it is a plain matmul with nothing to fuse.
    """
    __slots__ = ("p", "uv", "ij", "zv", "scale", "kb")

    def __init__(self, p, uv=None, ij=None, zv=None, scale=None, kb="jnp"):
        self.p = p
        self.uv = uv
        self.ij = ij
        self.zv = zv
        self.scale = scale
        self.kb = kb

    @classmethod
    def make(cls, params, subspace_nested=None, pert: Pert | None = None,
             kernel_backend: str | None = None):
        kb = kops.resolve_backend(kernel_backend)
        if pert is None:
            return cls(params, subspace_nested, None, None, None, kb)
        return cls(params, subspace_nested, pert.ij, pert.zv, pert.scale, kb)

    def __getitem__(self, k: str) -> "Bundle":
        return Bundle(self.p[k], _child(self.uv, k), _child(self.ij, k),
                      _child(self.zv, k), self.scale, self.kb)

    def __contains__(self, k: str) -> bool:
        return k in self.p

    # -- leaf accessors --------------------------------------------------

    def _rank1(self, k: str):
        """(u, v, s) for leaf k if perturbed, else None.  i/j may carry
        residual instance dims (e.g. experts) — u/v then gain those dims
        *last*: u = U[:, i] has shape (rows, *inst)."""
        ij = _child(self.ij, k)
        uv = _child(self.uv, k)
        if ij is None or uv is None or self.scale is None:
            return None
        return uv.U[:, ij.i], uv.V[:, ij.j], self.scale

    def dense(self, k: str, x: jax.Array, bias: str | None = None) -> jax.Array:
        """y = x @ W (+b), with the fused rank-1 epilogue when perturbed.
        W (n, m); x (..., n).  Scalar i/j only (scan/vmap already sliced).

        Perturbed + non-jnp backend: one ``ops.rank1_matmul`` kernel call —
        the rank-1 term rides the matmul's k-loop, W is streamed once."""
        W = self.p[k]
        r1 = self._rank1(k)
        if r1 is not None and self.kb != "jnp":
            u, v, s = r1
            y = kops.rank1_matmul(x.reshape(-1, x.shape[-1]), W, u, v, s,
                                  backend=self.kb)
            y = y.reshape(x.shape[:-1] + (W.shape[-1],))
        else:
            y = jnp.einsum("...n,nm->...m", x, W)
            if r1 is not None:
                u, v, s = r1
                y = y + s.astype(y.dtype) * jnp.einsum("...n,n->...", x, u.astype(x.dtype))[..., None] \
                    * v.astype(y.dtype)
        if bias is not None:
            y = y + self.vec(bias).astype(y.dtype)
        return y

    def dense_t(self, k: str, x: jax.Array) -> jax.Array:
        """y = x @ W^T — for tied-embedding logits.  W (m, n); x (..., n).
        Rank-1: x (W + s u v^T)^T = x W^T + s (x·v) u^T
        (``ops.rank1_matmul_t`` on the kernel backends)."""
        W = self.p[k]
        r1 = self._rank1(k)
        if r1 is not None and self.kb != "jnp":
            u, v, s = r1
            y = kops.rank1_matmul_t(x.reshape(-1, x.shape[-1]), W, u, v, s,
                                    backend=self.kb)
            return y.reshape(x.shape[:-1] + (W.shape[0],))
        y = jnp.einsum("...n,mn->...m", x, W)
        if r1 is not None:
            u, v, s = r1
            y = y + s.astype(y.dtype) * jnp.einsum("...n,n->...", x, v.astype(x.dtype))[..., None] \
                * u.astype(y.dtype)
        return y

    def embed(self, k: str, ids: jax.Array) -> jax.Array:
        """Perturbed embedding lookup: (E + s u v^T)[ids] = E[ids] + s·u[ids]·v^T."""
        E = self.p[k]
        out = E[ids]
        r1 = self._rank1(k)
        if r1 is not None:
            u, v, s = r1
            out = out + s.astype(out.dtype) * u[ids][..., None].astype(out.dtype) \
                * v.astype(out.dtype)
        return out

    def matw(self, k: str) -> jax.Array:
        """Materialized perturbed weight — for small leaves (conv kernels,
        dt_proj) where fusing is not worth it."""
        W = self.p[k]
        r1 = self._rank1(k)
        if r1 is None:
            return W
        u, v, s = r1
        # instance dims (if any) trail in u/v; move them in front of the outer
        if u.ndim == 1:
            z = u[:, None] * v[None, :]
        else:  # (rows, *inst) x (cols, *inst) -> (*inst, rows, cols)
            u = jnp.moveaxis(u, 0, -1)
            v = jnp.moveaxis(v, 0, -1)
            z = u[..., :, None] * v[..., None, :]
        return W + s.astype(W.dtype) * z.astype(W.dtype)

    def vec(self, k: str) -> jax.Array:
        """Vector leaf with its dense-Gaussian perturbation (paper's non-2D
        fallback)."""
        b = self.p[k]
        z = _child(self.zv, k)
        if z is None or self.scale is None:
            return b
        return b + self.scale.astype(b.dtype) * z.astype(b.dtype)

    def expert_dense(self, k: str, x: jax.Array,
                     weight_spec=None) -> jax.Array:
        """Batched expert matmul with per-expert rank-1 perturbations.
        x (E, C, n), W (E, n, m), coords per expert (E,).

        ``weight_spec``: optional PartitionSpec constraint applied to W at
        use-time.  Under fsdp_tp the stored weight shards its n (=d_model)
        axis over "data"; constraining the *used* weight to be replicated on
        that axis forces XLA to all-gather the weight (GBs) instead of
        psumming the (E,C,·) activation buffers (hundreds of GBs) — see
        EXPERIMENTS.md §Perf.
        """
        W = self.p[k]
        if weight_spec is not None and _mesh_active():
            W = jax.lax.with_sharding_constraint(W, weight_spec)
        r1 = self._rank1(k)
        if r1 is not None and self.kb != "jnp":
            u, v, s = r1          # u (n, E), v (m, E)
            return kops.rank1_matmul_expert(x, W, u, v, s, backend=self.kb)
        y = jnp.einsum("ecn,enm->ecm", x, W)
        if r1 is not None:
            u, v, s = r1          # u (n, E), v (m, E)
            xu = jnp.einsum("ecn,ne->ec", x, u.astype(x.dtype))
            y = y + s.astype(y.dtype) * xu[..., None] * v.T[:, None, :].astype(y.dtype)
        return y


def scan_xs(bundle_tree_params, pert: Pert | None, group_key: str):
    """xs trees for lax.scan over a group: params + coords + vector-z slices.
    (The subspace is NOT scanned — U/V are shared across instances.)"""
    p = bundle_tree_params[group_key]
    ij = _child(pert.ij, group_key) if pert is not None else None
    zv = _child(pert.zv, group_key) if pert is not None else None
    return p, ij, zv
