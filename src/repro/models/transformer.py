"""Composable decoder stack: spec builder + scanned forward + caches + loss.

A model is fully described by an ``ArchConfig``; this module turns it into

* ``arch_spec(cfg)``    — LeafSpec tree (init/sharding/SubCGE metadata source)
* ``forward(...)``      — train / prefill / decode forward, perturbation-aware
* ``init_cache(...)``   — stacked KV/SSM caches for the serve path
* ``lm_loss(...)``      — next-token CE (modality-frontend aware)

Layers within a group period are unrolled; periods are lax.scan'ed, so HLO
size scales with the period length, not depth.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerCfg
from repro.models import layers as L
from repro.models import params as plib
from repro.models.params import LeafSpec, matrix, vector
from repro.models.perturb import Bundle, Pert, _child

LEARNED_POS_LEN = 4_096  # OPT-style learned position table length


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------

def _norm_spec(s: dict, key: str, dim: int, cfg: ArchConfig, stack) -> None:
    s[key + "_scale"] = vector(dim, "embed", stack=stack, init="zeros")
    if cfg.norm == "layernorm":
        s[key + "_bias"] = vector(dim, "embed", stack=stack, init="zeros")


def _slot_spec(slot: LayerCfg, cfg: ArchConfig, reps: int) -> dict[str, LeafSpec]:
    stack = ((reps, "layers"),)
    d = cfg.d_model
    s: dict[str, LeafSpec] = {}

    if slot.mixer == "attn":
        a = slot.attn
        _norm_spec(s, "ln_attn", d, cfg, stack)
        if a.is_mla:
            nope, rd, vd = a.head_dim, a.rope_head_dim, (a.v_head_dim or a.head_dim)
            if a.q_lora > 0:
                s["wdq"] = matrix(d, a.q_lora, "embed", "mla_latent", stack=stack)
                s["q_ln_scale"] = vector(a.q_lora, "mla_latent", stack=stack, init="zeros")
                s["wuq"] = matrix(a.q_lora, a.n_heads * (nope + rd),
                                  "mla_latent", "heads_embed", stack=stack)
            else:
                s["wq"] = matrix(d, a.n_heads * (nope + rd),
                                 "embed", "heads_embed", stack=stack)
            s["wdkv"] = matrix(d, a.kv_lora + rd, "embed", "mla_latent", stack=stack)
            s["kv_ln_scale"] = vector(a.kv_lora, "mla_latent", stack=stack, init="zeros")
            s["wukv"] = matrix(a.kv_lora, a.n_heads * (nope + vd),
                               "mla_latent", "heads_embed", stack=stack)
            s["wo"] = matrix(a.n_heads * vd, d, "heads_embed", "embed", stack=stack)
        else:
            H, KV, hd = a.n_heads, a.n_kv_heads, a.head_dim
            s["wq"] = matrix(d, H * hd, "embed", "heads_embed", stack=stack)
            s["wk"] = matrix(d, KV * hd, "embed", "kv_embed", stack=stack)
            s["wv"] = matrix(d, KV * hd, "embed", "kv_embed", stack=stack)
            s["wo"] = matrix(H * hd, d, "heads_embed", "embed", stack=stack)
            if a.qkv_bias:
                s["bq"] = vector(H * hd, "heads_embed", stack=stack)
                s["bk"] = vector(KV * hd, "kv_embed", stack=stack)
                s["bv"] = vector(KV * hd, "kv_embed", stack=stack)
    elif slot.mixer == "mamba":
        m = slot.mamba
        Di, N, Kc = m.d_inner, m.d_state, m.d_conv
        dtr = m.dt_rank or -(-d // 16)
        _norm_spec(s, "ln_attn", d, cfg, stack)
        s["in_proj"] = matrix(d, 2 * Di, "embed", "mamba_inner", stack=stack)
        s["conv_w"] = matrix(Di, Kc, "mamba_inner", "conv", stack=stack)
        s["conv_b"] = vector(Di, "mamba_inner", stack=stack)
        s["x_proj"] = matrix(Di, dtr + 2 * N, "mamba_inner", "dt_rank", stack=stack)
        s["dt_proj"] = matrix(dtr, Di, "dt_rank", "mamba_inner", stack=stack)
        s["dt_bias"] = vector(Di, "mamba_inner", stack=stack, init="dt_bias")
        s["A_log"] = matrix(Di, N, "mamba_inner", "state", stack=stack, init="s4d")
        s["D_skip"] = vector(Di, "mamba_inner", stack=stack, init="ones")
        s["out_proj"] = matrix(Di, d, "mamba_inner", "embed", stack=stack)

    if slot.ffn == "dense":
        _norm_spec(s, "ln_mlp", d, cfg, stack)
        s["w1"] = matrix(d, slot.d_ff, "embed", "mlp", stack=stack)
        if cfg.gated_mlp:
            s["w3"] = matrix(d, slot.d_ff, "embed", "mlp", stack=stack)
        s["w2"] = matrix(slot.d_ff, d, "mlp", "embed", stack=stack)
    elif slot.ffn == "moe":
        mo = slot.moe
        estack = stack + ((mo.n_experts, "experts"),)
        _norm_spec(s, "ln_mlp", d, cfg, stack)
        s["router"] = matrix(d, mo.n_experts, "embed", "experts", stack=stack)
        # expert weights use their own d_model axis name ("expert_embed") so
        # policies can fsdp-shard the big expert tensors over "data" without
        # dragging the residual stream / attention weights along (§Perf)
        s["w1"] = matrix(d, mo.d_ff_expert, "expert_embed", "mlp", stack=estack)
        if cfg.gated_mlp:
            s["w3"] = matrix(d, mo.d_ff_expert, "expert_embed", "mlp", stack=estack)
        s["w2"] = matrix(mo.d_ff_expert, d, "mlp", "expert_embed", stack=estack)
        if mo.n_shared > 0:
            fs = mo.n_shared * mo.d_ff_expert
            s["sw1"] = matrix(d, fs, "embed", "mlp", stack=stack)
            if cfg.gated_mlp:
                s["sw3"] = matrix(d, fs, "embed", "mlp", stack=stack)
            s["sw2"] = matrix(fs, d, "mlp", "embed", stack=stack)
    return s


def arch_spec(cfg: ArchConfig) -> dict[str, Any]:
    spec: dict[str, Any] = {"embed": {}}
    spec["embed"]["tok"] = matrix(cfg.vocab, cfg.d_model, "vocab", "embed",
                                  scale=0.02)
    if not cfg.tie_embeddings:
        spec["embed"]["out"] = matrix(cfg.d_model, cfg.vocab, "embed", "vocab")
    _norm_spec(spec["embed"], "ln_f", cfg.d_model, cfg, ())
    if cfg.pos == "learned":
        spec["embed"]["pos"] = matrix(LEARNED_POS_LEN, cfg.d_model,
                                      None, "embed", scale=0.02)
    if cfg.frontend is not None:
        spec["frontend"] = {
            "proj": matrix(cfg.frontend.embed_dim, cfg.d_model, "vit", "embed"),
        }
    for gi, g in enumerate(cfg.groups):
        spec[f"g{gi}"] = {f"s{si}": _slot_spec(slot, cfg, g.reps)
                          for si, slot in enumerate(g.slots)}
    return spec


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _slot_cache(slot: LayerCfg, cfg: ArchConfig, reps: int, B: int,
                capacity: int, dtype) -> dict | None:
    if slot.mixer == "attn":
        a = slot.attn
        C = capacity if a.window is None else min(a.window, capacity)
        if a.is_mla:
            rd = a.rope_head_dim
            return {"ckv": jnp.zeros((reps, B, C, a.kv_lora), dtype),
                    "krope": jnp.zeros((reps, B, C, rd), dtype),
                    "kpos": jnp.full((reps, C), -1, jnp.int32)}
        return {"k": jnp.zeros((reps, B, C, a.n_kv_heads, a.head_dim), dtype),
                "v": jnp.zeros((reps, B, C, a.n_kv_heads, a.head_dim), dtype),
                "kpos": jnp.full((reps, C), -1, jnp.int32)}
    if slot.mixer == "mamba":
        m = slot.mamba
        return {"h": jnp.zeros((reps, B, m.d_inner, m.d_state), jnp.float32),
                "conv": jnp.zeros((reps, B, m.d_conv - 1, m.d_inner), dtype)}
    return None


def init_cache(cfg: ArchConfig, B: int, capacity: int, dtype=jnp.bfloat16):
    cache: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        cache[f"g{gi}"] = {f"s{si}": _slot_cache(slot, cfg, g.reps, B, capacity, dtype)
                           for si, slot in enumerate(g.slots)}
    return cache


def abstract_cache(cfg: ArchConfig, B: int, capacity: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, B, capacity, dtype))


# ---------------------------------------------------------------------------
# paged KV pool (serving; repro.serve / DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# Instead of one (B, capacity) buffer per request batch, serving keeps a
# shared pool of fixed-size pages per attention slot and a per-request page
# table (host side: repro.serve.paged_cache).  Pools are allocated with
# ``n_pages + 1`` physical pages: the extra LAST page is the dump page that
# inactive decode slots write into (same trick as the MoE overflow slot), so
# the decode step runs at a fixed batch width with no scatter corruption.

def check_paged_support(cfg: ArchConfig) -> None:
    """Paged serving covers standard (GQA) attention slots; MLA's compressed
    cache and Mamba's recurrent state need their own paging story (ROADMAP)."""
    if cfg.frontend is not None:
        raise ValueError("paged serving is text-decode only (frontend archs "
                         "serve through the monolithic path)")
    for g in cfg.groups:
        for slot in g.slots:
            if slot.mixer == "mamba":
                raise ValueError("paged serving does not support mamba slots")
            if slot.mixer == "attn" and slot.attn.is_mla:
                raise ValueError("paged serving does not support MLA slots")


def _slot_paged_pool(slot: LayerCfg, cfg: ArchConfig, reps: int, n_pages: int,
                     page_size: int, dtype) -> dict | None:
    if slot.mixer != "attn":
        return None
    a = slot.attn
    return {"k": jnp.zeros((reps, n_pages + 1, page_size, a.n_kv_heads,
                            a.head_dim), dtype),
            "v": jnp.zeros((reps, n_pages + 1, page_size, a.n_kv_heads,
                            a.head_dim), dtype)}


def init_paged_pool(cfg: ArchConfig, n_pages: int, page_size: int,
                    dtype=jnp.bfloat16):
    """Per-attention-slot page pools (+1 dump page; see module comment)."""
    check_paged_support(cfg)
    pool: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        pool[f"g{gi}"] = {f"s{si}": _slot_paged_pool(slot, cfg, g.reps,
                                                     n_pages, page_size, dtype)
                          for si, slot in enumerate(g.slots)}
    return pool


def abstract_paged_pool(cfg: ArchConfig, n_pages: int, page_size: int,
                        dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_paged_pool(cfg, n_pages, page_size,
                                                  dtype))


def write_prefill_to_pages(cfg: ArchConfig, cache: Any, pool: Any,
                           table: jax.Array, page_size: int) -> Any:
    """Scatter a freshly prefilled monolithic cache into pool pages.

    ``cache``: the (Bg, T)-shaped tree a prefill ``forward`` just filled;
    ``table``: (Bg, pages) int32 page rows for the Bg admitted requests.
    Prefill logits never read the cache layout (the T > 1 path attends the
    raw k/v), so prefill-then-scatter is bitwise the monolithic prefill.
    """
    out: dict[str, Any] = {}
    for gi, g in enumerate(cfg.groups):
        gk = f"g{gi}"
        out[gk] = {}
        for si, slot in enumerate(g.slots):
            sk = f"s{si}"
            if slot.mixer != "attn":
                out[gk][sk] = pool[gk][sk]
                continue
            c, p = cache[gk][sk], pool[gk][sk]
            # prefill caches are allocated with capacity == prompt length,
            # so slot s of the (full) ring holds absolute position s
            T = c["k"].shape[2]
            pos_vals = jnp.arange(T, dtype=jnp.int32)
            phys = table[:, pos_vals // page_size]            # (Bg, T)
            off = jnp.broadcast_to(pos_vals % page_size, phys.shape)
            out[gk][sk] = {
                "k": p["k"].at[:, phys, off].set(c["k"].astype(p["k"].dtype)),
                "v": p["v"].at[:, phys, off].set(c["v"].astype(p["v"].dtype)),
            }
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_slot(slot: LayerCfg, sb: Bundle, x: jax.Array, cache_slot,
                pos, cfg: ArchConfig, paged_table=None):
    new_cache = None
    if slot.mixer == "attn":
        h = L.norm(sb, "ln_attn", x, cfg.norm)
        mixer_cache = cache_slot if cache_slot is not None else None
        if paged_table is not None:
            if slot.attn.is_mla:
                raise ValueError("paged decode does not support MLA slots")
            y, new_cache = L.paged_attention(
                sb, h, slot.attn, pos, mixer_cache, paged_table,
                cfg.rope_theta,
                pos_kind="rope" if cfg.pos == "rope" else "none")
        elif slot.attn.is_mla:
            y, new_cache = L.mla_attention(sb, h, slot.attn, pos, mixer_cache,
                                           cfg.rope_theta)
        else:
            y, new_cache = L.attention(sb, h, slot.attn, pos, mixer_cache,
                                       cfg.rope_theta,
                                       pos_kind="rope" if cfg.pos == "rope" else "none")
        x = x + y
    elif slot.mixer == "mamba" and paged_table is not None:
        raise ValueError("paged decode does not support mamba slots")
    elif slot.mixer == "mamba":
        h = L.norm(sb, "ln_attn", x, cfg.norm)
        y, new_cache = L.mamba(sb, h, slot.mamba, cache_slot)
        x = x + y

    aux = jnp.zeros((), jnp.float32)
    if slot.ffn == "dense":
        h = L.norm(sb, "ln_mlp", x, cfg.norm)
        x = x + L.mlp(sb, h, cfg.act, cfg.gated_mlp)
    elif slot.ffn == "moe":
        h = L.norm(sb, "ln_mlp", x, cfg.norm)
        y, aux = L.moe(sb, h, slot.moe, cfg.act, cfg.gated_mlp,
                       gather_weights=cfg.moe_gather_weights)
        x = x + y
    return x, new_cache, aux


def forward(cfg: ArchConfig, params: Any, batch: dict, *,
            sub: Any = None, pert: Pert | None = None,
            cache: Any = None, pos=0, kernel_backend: str | None = None,
            paged_table: jax.Array | None = None):
    """Run the decoder.  Returns (logits, new_cache, aux_loss).

    batch: {"tokens": (B, T) int32, optional "embeds": (B, P, edim)} —
    ``embeds`` are the stubbed modality-frontend outputs, prepended after
    projection.  ``pos`` is the absolute position of tokens[:, 0].
    ``kernel_backend`` picks the implementation of the perturbed matmuls
    (None -> process default; see repro.kernels.ops / DESIGN.md §7).

    With ``paged_table`` set (the repro.serve decode path, DESIGN.md §10),
    ``cache`` is a paged pool tree (:func:`init_paged_pool`), ``pos`` is a
    per-request (B,) int32 position vector, T must be 1, and attention runs
    :func:`repro.models.layers.paged_attention` against the (B, Pb) table.
    """
    paged = paged_table is not None
    root = Bundle.make(params, sub, pert, kernel_backend)
    be = root["embed"]
    tokens = batch["tokens"]
    x = be.embed("tok", tokens)

    if "embeds" in batch and "frontend" in params:
        xf = root["frontend"].dense("proj", batch["embeds"].astype(x.dtype))
        x = jnp.concatenate([xf, x], axis=1)
    T = x.shape[1]
    if paged:
        q_pos = jnp.asarray(pos)[:, None] + jnp.arange(T)    # (B, T)
    else:
        q_pos = pos + jnp.arange(T)

    if cfg.pos == "learned":
        x = x + be.embed("pos", jnp.clip(q_pos, 0, LEARNED_POS_LEN - 1))
    elif cfg.pos == "sinusoidal":
        pe = L.sinusoidal_pos(q_pos, cfg.d_model)
        x = x + (pe if paged else pe[None]).astype(x.dtype)

    if cfg.residual_replicated:
        from jax.sharding import PartitionSpec as _P
        U = _P.UNCONSTRAINED
        x = jax.lax.with_sharding_constraint(
            x, _P(*([U] * (x.ndim - 1)), None))

    new_cache: dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    for gi, g in enumerate(cfg.groups):
        gk = f"g{gi}"
        gp = params[gk]
        gij = _child(pert.ij, gk) if pert is not None else None
        gzv = _child(pert.zv, gk) if pert is not None else None
        guv = _child(sub, gk)
        gcache = cache[gk] if cache is not None else None
        scale = pert.scale if pert is not None else None

        def body(carry, xs, g=g, guv=guv, scale=scale, kb=root.kb):
            xc, aux_c = carry
            pslice, ijslice, zvslice, cslice = xs
            ncs: dict[str, Any] = {}
            for si, slot in enumerate(g.slots):
                sk = f"s{si}"
                sb = Bundle(pslice[sk], _child(guv, sk), _child(ijslice, sk),
                            _child(zvslice, sk), scale, kb)
                cslot = cslice[sk] if cslice is not None else None
                xc, nc, aux = _apply_slot(slot, sb, xc, cslot, pos, cfg,
                                          paged_table=paged_table)
                ncs[sk] = nc
            return (xc, aux_c + aux), ncs

        (x, aux_total), ncache = jax.lax.scan(
            body, (x, aux_total), (gp, gij, gzv, gcache))
        new_cache[gk] = ncache

    x = L.norm(be, "ln_f", x, cfg.norm)
    if cfg.tie_embeddings:
        logits = be.dense_t("tok", x)
    else:
        logits = be.dense("out", x)
    return logits, (new_cache if cache is not None else None), aux_total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(cfg: ArchConfig, params: Any, batch: dict, *,
            sub: Any = None, pert: Pert | None = None,
            kernel_backend: str | None = None) -> jax.Array:
    """Mean next-token cross-entropy over the text segment (frontend embeds,
    if any, are context only)."""
    logits, _, aux = forward(cfg, params, batch, sub=sub, pert=pert,
                             kernel_backend=kernel_backend)
    tokens = batch["tokens"]
    off = logits.shape[1] - tokens.shape[1]          # n frontend embeds
    Tt = tokens.shape[1]
    lg = logits[:, off: off + Tt - 1].astype(jnp.float32)
    labels = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    # gold logit via masked reduction, NOT take_along_axis: a gather across a
    # vocab-sharded axis would force an all-gather of the full logits under
    # SPMD; the select+reduce keeps partial sums shard-local.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lg, 0.0), axis=-1)
    return jnp.mean(lse - gold) + aux


# ---------------------------------------------------------------------------
# convenience
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, seed: int = 0, dtype=jnp.float32):
    return plib.init_params(arch_spec(cfg), seed, dtype)


def count_params(cfg: ArchConfig) -> int:
    return plib.n_params(arch_spec(cfg))
