"""Parameter specs: shapes, logical sharding axes, SubCGE metadata.

No flax here — models are functional and parameters are nested dicts of
arrays.  A model definition first produces a *spec tree* (same nesting,
``LeafSpec`` leaves); everything else derives from it:

* ``init_params``     — deterministic initialization
* ``abstract_params`` — ShapeDtypeStruct stand-ins (dry-run, no allocation)
* ``tree_shardings``  — NamedSharding per leaf from logical→mesh rules
* ``subcge_meta``     — LeafMeta dict for the SubCGE machinery

Logical axes vocabulary (MaxText-style): "layers" (scan stacking),
"experts", "embed" (d_model), "mlp" (d_ff), "heads_embed" (H·hd fused),
"kv_embed" (KV·hd fused), "vocab", "mamba_inner", "state", "conv",
"dt_rank", "lora", "vit".  ``None`` means never sharded.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import seeds as seedlib
from repro.core.subcge import LeafMeta


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    n_batch_dims: int = 0                 # leading scan/expert instance dims
    init: str = "normal"                  # normal | zeros | ones
    scale: float | None = None            # None -> 1/sqrt(fan_in)
    frozen: bool = False                  # excluded from ZO perturbation

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)

    @property
    def fan_in(self) -> int:
        if len(self.shape) >= 2:
            return self.shape[-2]
        return self.shape[-1]


def matrix(rows: int, cols: int, raxis: str | None, caxis: str | None,
           stack: tuple[tuple[int, str | None], ...] = (), **kw) -> LeafSpec:
    """A (possibly stacked) 2D weight — SubCGE's bread and butter."""
    sdims = tuple(s for s, _ in stack)
    saxes = tuple(a for _, a in stack)
    return LeafSpec(sdims + (rows, cols), saxes + (raxis, caxis),
                    n_batch_dims=len(stack), **kw)


def vector(dim: int, axis: str | None,
           stack: tuple[tuple[int, str | None], ...] = (),
           init: str = "zeros", **kw) -> LeafSpec:
    sdims = tuple(s for s, _ in stack)
    saxes = tuple(a for _, a in stack)
    return LeafSpec(sdims + (dim,), saxes + (axis,),
                    n_batch_dims=len(stack), init=init, **kw)


# ---------------------------------------------------------------------------
# derivations
# ---------------------------------------------------------------------------

def init_params(specs: Any, seed: int, dtype=jnp.float32) -> Any:
    key = jax.random.PRNGKey(seed)

    def one(path: str, spec: LeafSpec):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "s4d":
            # Mamba A_log: log(1..N) broadcast over channels
            n_state = spec.shape[-1]
            row = jnp.log(jnp.arange(1, n_state + 1, dtype=jnp.float32))
            return jnp.broadcast_to(row, spec.shape).astype(dtype)
        if spec.init == "dt_bias":
            # softplus^-1(0.01) ≈ -4.6: small initial step sizes
            return jnp.full(spec.shape, -4.6, dtype)
        scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(spec.fan_in)
        k = seedlib.leaf_key(key, path)
        return (scale * jax.random.normal(k, spec.shape, jnp.float32)).astype(dtype)

    return seedlib.map_with_paths(one, specs)


def abstract_params(specs: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


def n_params(specs: Any) -> int:
    return sum(int(math.prod(s.shape)) for s in jax.tree.leaves(specs))


def subcge_meta(specs: Any) -> dict[str, LeafMeta]:
    meta: dict[str, LeafMeta] = {}

    def visit(path: str, spec: LeafSpec):
        meta[path] = LeafMeta(tuple(spec.shape), spec.n_batch_dims, spec.frozen)
        return spec

    seedlib.map_with_paths(visit, specs)
    return meta


# ---------------------------------------------------------------------------
# sharding policies
# ---------------------------------------------------------------------------

#: logical axis -> preferred mesh axis, in first-come-first-served order per
#: leaf (a mesh axis is used at most once per leaf).
POLICIES: dict[str, dict[str, str]] = {
    # tensor parallel only: weights over "model", everything else replicated
    "tp": {
        "mlp": "model", "heads_embed": "model", "kv_embed": "model",
        "vocab": "model", "experts": "model", "mamba_inner": "model",
        "lora": "model", "vit": "model",
    },
    # fsdp+tp: additionally shard the embed axis of weights over "data"
    # (ZeRO-3 style; XLA inserts per-scan-step all-gathers)
    "fsdp_tp": {
        "mlp": "model", "heads_embed": "model", "kv_embed": "model",
        "vocab": "model", "experts": "model", "mamba_inner": "model",
        "lora": "model", "vit": "model",
        "embed": "data", "expert_embed": "data", "dt_rank": "data",
    },
    # moe_fsdp (beyond-paper §Perf): ZeRO-3 only where it's needed — the
    # expert tensors (experts×model×data = 256-way) — while the residual
    # stream, attention and embeddings stay pure-TP (replicated over data).
    # Viable because ZO training keeps no grads/moments; pairs with
    # moe_gather_weights so the per-layer fsdp cost is a weight all-gather.
    "moe_fsdp": {
        "mlp": "model", "heads_embed": "model", "kv_embed": "model",
        "vocab": "model", "experts": "model", "mamba_inner": "model",
        "lora": "model", "vit": "model",
        "expert_embed": "data",
    },
    # expert-parallel (beyond-paper §Perf): experts over "data", expert-ff
    # over "model"; dense/attention weights column-parallel over "model"
    # only (replicated over data — viable because ZO training stores no
    # grads/moments).  Turns the FSDP d-contraction all-reduces of expert
    # buffers into token all-to-alls.
    "ep": {
        "experts": "data", "mlp": "model", "heads_embed": "model",
        "kv_embed": "model", "vocab": "model", "mamba_inner": "model",
        "lora": "model", "vit": "model",
    },
}


def spec_partition(axes: tuple[str | None, ...], rules: dict[str, str],
                   mesh: Mesh) -> P:
    """Logical axes -> PartitionSpec, FCFS on mesh axes, divisibility-checked
    by the caller via ``shard_or_none``."""
    used: set[str] = set()
    parts: list[str | None] = []
    for a in axes:
        m = rules.get(a) if a is not None else None
        if m is not None and m in mesh.axis_names and m not in used:
            used.add(m)
            parts.append(m)
        else:
            parts.append(None)
    return P(*parts)


def leaf_sharding(spec: LeafSpec, mesh: Mesh, rules: dict[str, str]) -> NamedSharding:
    parts = list(spec_partition(spec.axes, rules, mesh))
    # drop assignments that don't divide the dimension
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for d, m in enumerate(parts):
        if m is not None and spec.shape[d] % sizes[m] != 0:
            parts[d] = None
    return NamedSharding(mesh, P(*parts))


def tree_shardings(specs: Any, mesh: Mesh, policy: str) -> Any:
    rules = POLICIES[policy]
    return jax.tree.map(lambda s: leaf_sharding(s, mesh, rules), specs)


def subspace_shardings(specs: Any, mesh: Mesh, policy: str) -> dict[str, Any]:
    """Shardings for the SubCGE subspace dict: U follows the leaf's row axis,
    V follows its column axis (rank axis replicated)."""
    rules = POLICIES[policy]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out: dict[str, Any] = {}

    def visit(path: str, spec: LeafSpec):
        if spec.frozen or len(spec.shape) - spec.n_batch_dims != 2:
            return spec
        rax, cax = spec.axes[-2], spec.axes[-1]
        rows, cols = spec.shape[-2], spec.shape[-1]
        rm = rules.get(rax) if rax else None
        cm = rules.get(cax) if cax else None
        if rm is not None and rows % sizes.get(rm, 1) != 0:
            rm = None
        if cm is not None and cols % sizes.get(cm, 1) != 0:
            cm = None
        out[path] = (NamedSharding(mesh, P(rm, None)),
                     NamedSharding(mesh, P(cm, None)))
        return spec

    seedlib.map_with_paths(visit, specs)
    return out


# ---------------------------------------------------------------------------
# path utilities
# ---------------------------------------------------------------------------

def nest(flat: dict[str, Any]) -> dict[str, Any]:
    """{'a/b': x} -> {'a': {'b': x}} — used to turn path-keyed SubCGE dicts
    into trees that mirror the params nesting."""
    out: dict[str, Any] = {}
    for path, v in flat.items():
        node = out
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return out


def flatten_paths(tree: Any) -> dict[str, Any]:
    out: dict[str, Any] = {}

    def visit(path: str, leaf):
        out[path] = leaf
        return leaf

    seedlib.map_with_paths(visit, tree)
    return out
