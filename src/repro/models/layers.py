"""Functional layer library (pure JAX, perturbation-aware).

Every layer takes a ``Bundle`` (params + shared subspace + per-client
perturbation view) so the same code serves: plain forward (serving, FO
baselines), ZO-perturbed dual forwards (SeedFlood training), at any scale.

Cache convention (decode/prefill): every attention slot owns
``{"k": (B,C,KV,hd), "v": (B,C,KV,hd), "kpos": (C,) int32}`` where C is the
cache capacity (full seq, or the sliding window for local layers — a ring
buffer addressed by ``pos % C``; ``kpos`` records which absolute position a
slot holds, and masking is derived from it, so ring and full caches share one
code path).  Mamba slots own ``{"h": (B,Di,N), "conv": (B,Kc-1,Di)}``.
MLA slots own the *compressed* cache ``{"ckv": (B,C,kv_lora),
"krope": (B,C,rd), "kpos": (C,)}`` and decode runs the absorbed formulation.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg, MambaCfg, MoECfg
from repro.models.perturb import Bundle

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms / activations / positions
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # f32 only for the variance STATISTIC; the normalizing multiply stays in
    # x.dtype.  Keeping the full activation out of f32 matters under TP: the
    # row-parallel psum feeding this norm otherwise gets its convert hoisted
    # above the all-reduce and the wire payload doubles (observed on
    # qwen2-72b: 4×80 f32[...,8192] all-reduces).  Exact no-op for f32 runs.
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale.astype(x.dtype))


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(b: Bundle, key: str, x: jax.Array, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layernorm(x, b.vec(key + "_scale"), b.vec(key + "_bias"))
    return rmsnorm(x, b.vec(key + "_scale"))


ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x (..., T, H, hd) [hd even], positions (T,) or, for
    the paged-decode path, (B, T) per-request positions (the cos/sin tables
    broadcast over the head axis either way; values for equal positions are
    bitwise identical to the unbatched path — same elementwise ops)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jax.Array, dim: int) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def attn_mask(q_pos: jax.Array, k_pos: jax.Array,
              window: int | None) -> jax.Array:
    """(T, S) boolean mask: causal, optionally sliding-window, and k-slot
    validity (kpos = -1 marks an unwritten ring slot).  Positions may carry
    a leading batch dim — (B, T)/(B, S) — for per-request paged decode, in
    which case the mask is (B, T, S)."""
    m = (k_pos[..., None, :] <= q_pos[..., :, None]) & (k_pos[..., None, :] >= 0)
    if window is not None:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def attn_core(q: jax.Array, k: jax.Array, v: jax.Array, q_pos: jax.Array,
              k_pos: jax.Array, window: int | None) -> jax.Array:
    """Grouped-query attention.  q (B,T,H,hd), k/v (B,S,KV,hd) -> (B,T,H*hd).
    Positions are shared (T,)/(S,) or per-request (B,T)/(B,S)."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
    logits *= 1.0 / math.sqrt(hd)
    mask = attn_mask(q_pos, k_pos, window)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(B, T, H * hd)


def _ring_write(cache_k: jax.Array, cache_v: jax.Array, kpos: jax.Array,
                k: jax.Array, v: jax.Array, pos: jax.Array):
    """Write T new entries ending at absolute position pos+T-1 into a ring
    cache of capacity C (full caches are rings with C >= seq)."""
    C = cache_k.shape[1]
    T = k.shape[1]
    if T >= C:  # prefill writing the whole cache: keep the last C positions
        keep = T - C
        new_pos = pos + jnp.arange(keep, T)
        slots = new_pos % C
        ck = cache_k.at[:, slots].set(k[:, keep:])
        cv = cache_v.at[:, slots].set(v[:, keep:])
        np_ = kpos.at[slots].set(new_pos)
    else:
        new_pos = pos + jnp.arange(T)
        slots = new_pos % C
        ck = cache_k.at[:, slots].set(k)
        cv = cache_v.at[:, slots].set(v)
        np_ = kpos.at[slots].set(new_pos)
    return ck, cv, np_


def attention(b: Bundle, x: jax.Array, acfg: AttnCfg, pos,
              cache: dict | None, rope_theta: float, pos_kind: str = "rope"):
    """Standard (GQA) attention.  Returns (y, new_cache)."""
    B, T, D = x.shape
    H, KV, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    q = b.dense("wq", x, bias="bq" if acfg.qkv_bias else None).reshape(B, T, H, hd)
    k = b.dense("wk", x, bias="bk" if acfg.qkv_bias else None).reshape(B, T, KV, hd)
    v = b.dense("wv", x, bias="bv" if acfg.qkv_bias else None).reshape(B, T, KV, hd)

    q_pos = pos + jnp.arange(T)
    if pos_kind == "rope":
        q = rope(q, q_pos, rope_theta)
        k = rope(k, q_pos, rope_theta)

    if cache is None:
        out = attn_core(q, k, v, q_pos, q_pos, acfg.window)
        new_cache = None
    else:
        ck, cv, kpos = _ring_write(cache["k"], cache["v"], cache["kpos"],
                                   k.astype(cache["k"].dtype),
                                   v.astype(cache["v"].dtype), pos)
        new_cache = {"k": ck, "v": cv, "kpos": kpos}
        if T > 1:
            # fresh prefill: attend over the full new k/v (the ring cache may
            # already have evicted early positions for windowed layers)
            out = attn_core(q, k, v, q_pos, q_pos, acfg.window)
        else:
            out = attn_core(q, ck, cv, q_pos, kpos, acfg.window)

    y = b.dense("wo", out)
    return y, new_cache


def paged_attention(b: Bundle, x: jax.Array, acfg: AttnCfg, pos_b: jax.Array,
                    pages: dict, table: jax.Array, rope_theta: float,
                    pos_kind: str = "rope"):
    """Decode-only (T == 1) GQA attention over a paged KV pool.

    ``pages``: one rep-slice of the pool, ``{"k": (P, page, KV, hd),
    "v": (P, page, KV, hd)}`` where the LAST physical page (index P-1) is the
    dump page — inactive request slots point every table entry at it, so
    their scatter writes land somewhere no live request ever gathers.
    ``table``: (B, Pb) int32 physical page ids per request slot, in logical
    order (entry p holds positions [p·page, (p+1)·page)); unreserved entries
    point at the dump page.  ``pos_b``: (B,) int32 absolute position of the
    incoming token per request.

    The gathered width S = Pb·page plays the role of the monolithic cache
    capacity; positions s > pos_b mask to exact-zero probability (softmax of
    -1e30 underflows), so stale page contents contribute exact +0.0 and a
    gather whose width equals the monolithic capacity is bitwise the ring
    path.  Returns (y, new_pages).
    """
    B, T, D = x.shape
    if T != 1:
        raise ValueError("paged_attention is decode-only (got T="
                         f"{T}; prefill goes through the monolithic path "
                         "and is scattered into pages afterwards)")
    H, KV, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    q = b.dense("wq", x, bias="bq" if acfg.qkv_bias else None).reshape(B, T, H, hd)
    k = b.dense("wk", x, bias="bk" if acfg.qkv_bias else None).reshape(B, T, KV, hd)
    v = b.dense("wv", x, bias="bv" if acfg.qkv_bias else None).reshape(B, T, KV, hd)

    q_pos = pos_b[:, None] + jnp.arange(T)                     # (B, 1)
    if pos_kind == "rope":
        q = rope(q, q_pos, rope_theta)
        k = rope(k, q_pos, rope_theta)

    page = pages["k"].shape[1]
    Pb = table.shape[1]
    phys = jnp.take_along_axis(table, (pos_b // page)[:, None], axis=1)[:, 0]
    off = pos_b % page
    kp = pages["k"].at[phys, off].set(k[:, 0].astype(pages["k"].dtype))
    vp = pages["v"].at[phys, off].set(v[:, 0].astype(pages["v"].dtype))

    S = Pb * page
    kg = kp[table].reshape(B, S, KV, hd)
    vg = vp[table].reshape(B, S, KV, hd)
    s_iota = jnp.arange(S, dtype=jnp.int32)[None, :]
    k_pos = jnp.where(s_iota <= pos_b[:, None], s_iota, -1)    # (B, S)
    out = attn_core(q, kg, vg, q_pos, k_pos, acfg.window)
    y = b.dense("wo", out)
    return y, {"k": kp, "v": vp}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — low-rank joint KV compression, decoupled RoPE
# ---------------------------------------------------------------------------

def _mla_dims(acfg: AttnCfg):
    nope = acfg.head_dim
    rd = acfg.rope_head_dim
    vd = acfg.v_head_dim or acfg.head_dim
    return nope, rd, vd


def mla_attention(b: Bundle, x: jax.Array, acfg: AttnCfg, pos,
                  cache: dict | None, rope_theta: float):
    """Multi-head Latent Attention.  Train/prefill expand the compressed KV;
    decode (T==1 with cache) uses the absorbed formulation so per-token cost
    is O(S·H·(kv_lora+rd)) instead of re-expanding the whole cache."""
    B, T, D = x.shape
    H = acfg.n_heads
    nope, rd, vd = _mla_dims(acfg)
    q_pos = pos + jnp.arange(T)

    # --- queries ---------------------------------------------------------
    if acfg.q_lora > 0:
        cq = b.dense("wdq", x)
        cq = rmsnorm(cq, b.vec("q_ln_scale"))
        q = b.dense("wuq", cq).reshape(B, T, H, nope + rd)
    else:
        q = b.dense("wq", x).reshape(B, T, H, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, q_pos, rope_theta)

    # --- compressed KV + decoupled shared k_rope --------------------------
    dkv = b.dense("wdkv", x)                       # (B,T,kv_lora + rd)
    ckv_new, krope_new = dkv[..., :acfg.kv_lora], dkv[..., acfg.kv_lora:]
    ckv_new = rmsnorm(ckv_new, b.vec("kv_ln_scale"))
    krope_new = rope(krope_new[:, :, None, :], q_pos, rope_theta)[:, :, 0, :]

    wukv = b.p["wukv"].reshape(acfg.kv_lora, H, nope + vd)
    scale = 1.0 / math.sqrt(nope + rd)

    if cache is not None and T == 1:
        # absorbed decode
        C = cache["ckv"].shape[1]
        slot = pos % C
        ckv = cache["ckv"].at[:, slot].set(ckv_new[:, 0].astype(cache["ckv"].dtype))
        krope = cache["krope"].at[:, slot].set(krope_new[:, 0].astype(cache["krope"].dtype))
        kpos = cache["kpos"].at[slot].set(pos)

        wuk = wukv[..., :nope]                      # (kv_lora, H, nope)
        wuv = wukv[..., nope:]                      # (kv_lora, H, vd)
        q_abs = jnp.einsum("bthn,lhn->bthl", q_nope, wuk)      # (B,1,H,kv_lora)
        lg = jnp.einsum("bthl,bsl->bhts", q_abs, ckv)
        lg = lg + jnp.einsum("bthr,bsr->bhts", q_rope, krope)
        lg = (lg.astype(jnp.float32) * scale)
        mask = attn_mask(q_pos, kpos, acfg.window)
        lg = jnp.where(mask[None, None], lg, _NEG_INF)
        probs = jax.nn.softmax(lg, axis=-1).astype(ckv.dtype)
        out_c = jnp.einsum("bhts,bsl->bthl", probs, ckv)
        out = jnp.einsum("bthl,lhv->bthv", out_c, wuv)
        y = b.dense("wo", out.reshape(B, T, H * vd))
        return y, {"ckv": ckv, "krope": krope, "kpos": kpos}

    # train / prefill: expand
    kv = jnp.einsum("btl,lhe->bthe", ckv_new, wukv)            # (B,T,H,nope+vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    lg = jnp.einsum("bthn,bshn->bhts", q_nope, k_nope)
    lg = lg + jnp.einsum("bthr,bsr->bhts", q_rope, krope_new)
    lg = lg.astype(jnp.float32) * scale
    mask = attn_mask(q_pos, q_pos, acfg.window)
    lg = jnp.where(mask[None, None], lg, _NEG_INF)
    probs = jax.nn.softmax(lg, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhts,bshv->bthv", probs, v).reshape(B, T, H * vd)
    y = b.dense("wo", out)

    new_cache = None
    if cache is not None:  # prefill fills the compressed cache
        ckv_c, krope_c, kpos = cache["ckv"], cache["krope"], cache["kpos"]
        Cc = ckv_c.shape[1]
        keep = max(0, T - Cc)
        npos = pos + jnp.arange(keep, T)
        slots = npos % Cc
        ckv_c = ckv_c.at[:, slots].set(ckv_new[:, keep:].astype(ckv_c.dtype))
        krope_c = krope_c.at[:, slots].set(krope_new[:, keep:].astype(krope_c.dtype))
        kpos = kpos.at[slots].set(npos)
        new_cache = {"ckv": ckv_c, "krope": krope_c, "kpos": kpos}
    return y, new_cache


# ---------------------------------------------------------------------------
# Mamba-1 (selective SSM)
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x (B,T,Di), w (Di,Kc)."""
    Kc = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (Kc - 1, 0), (0, 0)))
    out = sum(xp[:, k:k + x.shape[1]] * w[:, k].astype(x.dtype) for k in range(Kc))
    return out + bias.astype(x.dtype)


def _ssm_chunked(a: jax.Array, bx: jax.Array, h0: jax.Array, chunk: int):
    """h_t = a_t h_{t-1} + bx_t, parallel within chunks of size ``chunk``.
    a/bx (B,T,Di,N); h0 (B,Di,N).  Returns (h_all (B,T,Di,N), h_last)."""
    B, T, Di, N = a.shape
    ck = min(chunk, T)
    while T % ck != 0:
        ck -= 1
    nc = T // ck
    a_c = a.reshape(B, nc, ck, Di, N)
    b_c = bx.reshape(B, nc, ck, Di, N)

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    def body(h, xs):
        ac, bc = xs                                  # (B,ck,Di,N)
        Acum, Bcum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = Acum * h[:, None] + Bcum
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(
        body, h0, (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0)))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(B, T, Di, N)
    return h_all, h_last


def mamba(b: Bundle, x: jax.Array, mcfg: MambaCfg, cache: dict | None):
    """Mamba-1 block.  Returns (y, new_cache)."""
    B, T, D = x.shape
    Di, N, Kc = mcfg.d_inner, mcfg.d_state, mcfg.d_conv
    dtr = mcfg.dt_rank or -(-D // 16)

    xz = b.dense("in_proj", x)                        # (B,T,2Di)
    xin, z = jnp.split(xz, 2, axis=-1)

    conv_w = b.matw("conv_w")                         # (Di,Kc) small
    if cache is not None and T == 1:
        full = jnp.concatenate([cache["conv"], xin], axis=1)   # (B,Kc,Di)
        xc = jnp.einsum("bkd,dk->bd", full, conv_w.astype(full.dtype))[:, None]
        xc = xc + b.vec("conv_b").astype(xc.dtype)
        new_conv = full[:, 1:]
    else:
        xc = _causal_conv(xin, conv_w, b.vec("conv_b"))
        new_conv = xin[:, -(Kc - 1):] if cache is not None else None
    xc = jax.nn.silu(xc)

    xdb = b.dense("x_proj", xc)                       # (B,T,dtr+2N)
    dt_in, B_in, C_in = jnp.split(xdb, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(b.dense("dt_proj", dt_in) + b.vec("dt_bias").astype(x.dtype))
    A = -jnp.exp(b.matw("A_log").astype(jnp.float32))  # (Di,N)

    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])       # (B,T,Di,N)
    bx = (dt * xc).astype(jnp.float32)[..., None] * B_in.astype(jnp.float32)[..., None, :]

    if cache is not None and T == 1:
        h = a[:, 0] * cache["h"] + bx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, C_in.astype(jnp.float32)[:, 0])[:, None]
        new_h = h
    else:
        h0 = cache["h"] if cache is not None else jnp.zeros((B, Di, N), jnp.float32)
        h_all, h_last = _ssm_chunked(a, bx, h0, mcfg.chunk)
        y = jnp.einsum("btdn,btn->btd", h_all, C_in.astype(jnp.float32))
        new_h = h_last if cache is not None else None

    y = y.astype(x.dtype) + b.vec("D_skip").astype(x.dtype) * xc
    y = y * jax.nn.silu(z)
    out = b.dense("out_proj", y)
    new_cache = None if cache is None else {"h": new_h, "conv": new_conv}
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs / MoE
# ---------------------------------------------------------------------------

def mlp(b: Bundle, x: jax.Array, act: str, gated: bool) -> jax.Array:
    f = ACTS[act]
    if gated:
        h = f(b.dense("w1", x)) * b.dense("w3", x)
    else:
        h = f(b.dense("w1", x))
    return b.dense("w2", h)


def _dispatch_indices(idx: jax.Array, n_experts: int, capacity: int):
    """Position of every (token, slot) assignment inside its expert's buffer.
    idx (T, k) -> pos (T, k) int32 and keep-mask (pos < capacity).
    Sequential over the k slots (tiny) to keep memory at O(T·E)."""
    T, K = idx.shape

    def body(counts, idx_s):
        oh = jax.nn.one_hot(idx_s, n_experts, dtype=jnp.int32)       # (T,E)
        pos_all = counts[None, :] + jnp.cumsum(oh, axis=0) - oh
        pos_s = jnp.take_along_axis(pos_all, idx_s[:, None], axis=1)[:, 0]
        return counts + oh.sum(axis=0), pos_s

    _, pos = jax.lax.scan(body, jnp.zeros((n_experts,), jnp.int32), idx.T)
    pos = pos.T                                                       # (T,k)
    return pos, pos < capacity


def moe(b: Bundle, x: jax.Array, mcfg: MoECfg, act: str, gated: bool,
        gather_weights: bool = False):
    """Top-k capacity-dispatch MoE.  x (B,T,D) -> (y, aux_loss).

    Compute is E×C×d×f ≈ top-k × dense-equivalent (cost_analysis reflects
    *active* FLOPs).  Experts shard over the "model" mesh axis.
    ``gather_weights``: constrain expert weights to be data-replicated at
    use (all-gather GBs of weights instead of psumming (E,C,·) activation
    buffers — the §Perf fsdp-MoE fix).
    """
    from jax.sharding import PartitionSpec as P
    wspec = P("model", None, None) if gather_weights else None
    B, T, D = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    xt = x.reshape(B * T, D)
    n_tok = B * T

    logits = b.dense("router", xt).astype(jnp.float32)               # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                            # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(math.ceil(n_tok * K / E * mcfg.capacity_factor)))
    pos, keep = _dispatch_indices(top_i, E, capacity)

    dest = jnp.where(keep, top_i * capacity + pos, E * capacity)      # overflow -> dump slot
    xbuf = jnp.zeros((E * capacity + 1, D), x.dtype)
    flat_dest = dest.reshape(-1)
    xbuf = xbuf.at[flat_dest].set(jnp.repeat(xt, K, axis=0)
                                  .reshape(n_tok, K, D).reshape(-1, D))
    xe = xbuf[:E * capacity].reshape(E, capacity, D)

    f = ACTS[act]
    if gated:
        h = f(b.expert_dense("w1", xe, wspec)) * b.expert_dense("w3", xe, wspec)
    else:
        h = f(b.expert_dense("w1", xe, wspec))
    ye = b.expert_dense("w2", h, wspec)                               # (E,C,D)

    # combine via scatter-ADD (not gather-then-weight): each expert shard
    # adds its pre-weighted (T,D) partial, so the cross-shard reduction
    # carries (T,D) instead of (T,k,D) — k× less wire (§Perf: kimi combine
    # all-reduce was 46% of step collectives at (T,8,D))
    slot_tok = jnp.zeros((E * capacity + 1,), jnp.int32).at[flat_dest].set(
        jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), K))
    slot_w = jnp.zeros((E * capacity + 1,), jnp.float32).at[flat_dest].set(
        (top_p * keep).reshape(-1))
    y = jnp.zeros((n_tok + 1, D), ye.dtype).at[slot_tok[:E * capacity]].add(
        ye.reshape(E * capacity, D)
        * slot_w[:E * capacity, None].astype(ye.dtype))[:n_tok]

    if mcfg.n_shared > 0:  # always-on shared experts (keys sw1/sw3/sw2)
        if gated:
            hs = f(b.dense("sw1", xt)) * b.dense("sw3", xt)
        else:
            hs = f(b.dense("sw1", xt))
        y = y + b.dense("sw2", hs)

    # load-balance auxiliary (Switch-style): E * Σ_e f_e · p̄_e
    me = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = mcfg.router_aux * E * jnp.sum(me * ce)
    return y.reshape(B, T, D), aux
