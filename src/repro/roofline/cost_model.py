"""Analytic FLOP/byte cost model per (arch × shape × step-kind).

Why analytic: XLA's HloCostAnalysis counts while-loop bodies exactly ONCE
(verified in tests/test_roofline.py), so ``compiled.cost_analysis()`` on a
scanned decoder undercounts by ~n_layers.  We therefore compute the
compute/memory roofline numerators from the architecture itself — every
matmul, attention score, SSM scan and MoE dispatch — and use cost_analysis
as a cross-check on unrolled small configs (test asserts agreement within
5%).  Collective bytes DO come from the compiled HLO (they depend on XLA's
partitioning choices), with while-body trip-count correction — see
analysis.parse_collectives_corrected.

Conventions:
* flops: 2·m·k·n per GEMM; attention 2·B·H·T·S·hd for scores and the same
  for values (causal/self-attention halves S for train/prefill).
* bytes: every GEMM reads A, B and writes C once (perfect fusion of
  elementwise ops into their producers — the roofline-optimistic model).
* ZO train: 2 forwards + the SubCGE update (scatter + U A V^T per leaf).
  No backward, no optimizer state traffic — this is the method's structural
  win and it shows in the tables.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, InputShape, LayerCfg


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def gemm(self, m: float, k: float, n: float, db: int = 2,
             batch: float = 1.0) -> None:
        self.flops += batch * 2.0 * m * k * n
        self.bytes += batch * db * (m * k + k * n + m * n)

    def ew(self, n_elems: float, flops_per: float = 1.0, db: int = 2,
           reads: int = 1, writes: int = 1) -> None:
        self.flops += n_elems * flops_per
        self.bytes += n_elems * db * (reads + writes)

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += times * other.flops
        self.bytes += times * other.bytes


def _attn_cost(slot: LayerCfg, cfg: ArchConfig, B: float, T: float,
               S: float, causal: bool, db: int) -> Cost:
    c = Cost()
    a = slot.attn
    d = cfg.d_model
    if a.window is not None:
        S = min(S, a.window)
    s_eff = S * (0.5 if (causal and T > 1 and a.window is None) else 1.0)
    if a.is_mla:
        nope, rd = a.head_dim, a.rope_head_dim
        vd = a.v_head_dim or a.head_dim
        H = a.n_heads
        if a.q_lora:
            c.gemm(B * T, d, a.q_lora, db)
            c.gemm(B * T, a.q_lora, H * (nope + rd), db)
        else:
            c.gemm(B * T, d, H * (nope + rd), db)
        c.gemm(B * T, d, a.kv_lora + rd, db)
        if T == 1:  # absorbed decode
            c.gemm(B * H, nope, a.kv_lora, db)                 # q absorption
            c.gemm(B * H * T, a.kv_lora + rd, s_eff, db)       # scores
            c.gemm(B * H * T, s_eff, a.kv_lora, db)            # values (compressed)
            c.gemm(B * H * T, a.kv_lora, vd, db)               # out expand
        else:
            c.gemm(B * T, a.kv_lora, H * (nope + vd), db)      # expand KV
            c.gemm(B * H * T, nope + rd, s_eff, db)
            c.gemm(B * H * T, s_eff, vd, db)
        c.gemm(B * T, H * vd, d, db)
    else:
        H, KV, hd = a.n_heads, a.n_kv_heads, a.head_dim
        c.gemm(B * T, d, (H + 2 * KV) * hd, db)                # qkv
        c.gemm(B * H * T, hd, s_eff, db)                       # scores
        c.gemm(B * H * T, s_eff, hd, db)                       # values
        c.gemm(B * T, H * hd, d, db)                           # out
    return c


def _mamba_cost(slot: LayerCfg, cfg: ArchConfig, B: float, T: float,
                db: int) -> Cost:
    c = Cost()
    m = slot.mamba
    d = cfg.d_model
    Di, N, Kc = m.d_inner, m.d_state, m.d_conv
    dtr = m.dt_rank or -(-d // 16)
    c.gemm(B * T, d, 2 * Di, db)
    c.ew(B * T * Di, flops_per=2 * Kc, db=db)                  # depthwise conv
    c.gemm(B * T, Di, dtr + 2 * N, db)
    c.gemm(B * T, dtr, Di, db)
    # selective scan: a=exp(dt·A), h=a·h+b, y=C·h  ≈ 10 flops/state-elem;
    # state traffic (B,T,Di,N) read+write in f32
    c.ew(B * T * Di * N, flops_per=10.0, db=4)
    c.gemm(B * T, Di, d, db)
    return c


def _ffn_cost(slot: LayerCfg, cfg: ArchConfig, B: float, T: float,
              db: int) -> Cost:
    c = Cost()
    d = cfg.d_model
    nmat = 3 if cfg.gated_mlp else 2
    if slot.ffn == "dense":
        c.gemm(B * T, d, slot.d_ff, db, batch=nmat - 1)
        c.gemm(B * T, slot.d_ff, d, db)
    elif slot.ffn == "moe":
        mo = slot.moe
        c.gemm(B * T, d, mo.n_experts, db)                     # router
        ec = mo.capacity_factor * mo.top_k * B * T             # Σ_e C_e tokens
        c.gemm(ec, d, mo.d_ff_expert, db, batch=nmat - 1)
        c.gemm(ec, mo.d_ff_expert, d, db)
        c.ew(2 * ec * d, flops_per=0.0, db=db)                 # dispatch/combine copies
        if mo.n_shared:
            fs = mo.n_shared * mo.d_ff_expert
            c.gemm(B * T, d, fs, db, batch=nmat - 1)
            c.gemm(B * T, fs, d, db)
    return c


def forward_cost(cfg: ArchConfig, B: float, T: float, ctx: float,
                 causal: bool = True, db: int = 2) -> Cost:
    """One forward pass.  ``ctx``: attention context length (cache for
    decode, == T for train/prefill)."""
    c = Cost()
    d = cfg.d_model
    for slot in cfg.layer_cfgs():
        if slot.mixer == "attn":
            c.add(_attn_cost(slot, cfg, B, T, ctx, causal, db))
        elif slot.mixer == "mamba":
            c.add(_mamba_cost(slot, cfg, B, T, db))
        c.ew(B * T * d, flops_per=8.0, db=db, reads=2, writes=1)  # norms+residual
        c.add(_ffn_cost(slot, cfg, B, T, db))
    # embeddings: gather read + logits gemm
    c.ew(B * T * d, flops_per=0.0, db=db)
    c.gemm(B * T, d, cfg.vocab, db)
    if cfg.frontend is not None and T > 1:
        c.gemm(B * cfg.frontend.n_embeds, cfg.frontend.embed_dim, d, db)
    return c


def subcge_update_cost(cfg: ArchConfig, rank: int, n_clients: int,
                       db: int = 2) -> Cost:
    """Scatter n coefficients + U A V^T per 2D leaf instance (eq. 10)."""
    from repro.models import params as plib
    from repro.models import transformer as tf
    c = Cost()
    flat = plib.flatten_paths(tf.arch_spec(cfg))
    for path, leaf in flat.items():
        tdims = leaf.shape[leaf.n_batch_dims:]
        inst = math.prod(leaf.shape[: leaf.n_batch_dims]) or 1
        if len(tdims) == 2:
            n, m = tdims
            c.gemm(n, rank, rank, db, batch=inst)              # U A
            c.gemm(n, rank, m, db, batch=inst)                 # (UA) V^T
            c.ew(inst * n * m, flops_per=1.0, db=db)           # W += Δ
        else:
            # dense-Gaussian fallback: n_clients axpys + RNG
            sz = math.prod(tdims) * inst
            c.ew(sz * n_clients, flops_per=4.0, db=4)
    return c


def step_cost(cfg: ArchConfig, shape: InputShape, kind: str, *,
              rank: int = 32, n_clients: int = 16, db: int = 2) -> Cost:
    B, T = shape.global_batch, shape.seq
    c = Cost()
    if kind == "train":            # SeedFlood ZO: two forwards + update
        f = forward_cost(cfg, B, T, T, causal=True, db=db)
        c.add(f, times=2.0)
        c.add(subcge_update_cost(cfg, rank, n_clients, db))
    elif kind == "train_dsgd":     # FO: fwd + bwd(≈2×fwd) + update + gossip
        f = forward_cost(cfg, B, T, T, causal=True, db=db)
        c.add(f, times=3.0)
    elif kind == "prefill":
        c.add(forward_cost(cfg, B, T, T, causal=True, db=db))
    elif kind == "decode":
        c.add(forward_cost(cfg, B, 1.0, T, causal=False, db=db))
    else:
        raise ValueError(kind)
    return c
