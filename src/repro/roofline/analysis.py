"""Roofline-term extraction from compiled dry-run artifacts.

Three terms, in seconds, per (arch × shape × mesh):

    compute    = HLO_FLOPs            / (chips × PEAK_FLOPS_BF16)
    memory     = HLO_bytes_accessed   / (chips × HBM_BW)
    collective = collective_bytes     / (chips × ICI_BW)

``cost_analysis`` supplies flops / bytes accessed; collective bytes are NOT
in cost_analysis, so we parse the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's result shape is sized in bytes and weighted by an op-specific traffic
factor (ring-algorithm effective bytes moved per participating device):

    all-reduce      2·(k-1)/k · size     (reduce-scatter + all-gather)
    all-gather      (k-1)/k · size       (size = result)
    reduce-scatter  (k-1)/k · size       (size = operand ≈ result·k)
    all-to-all      (k-1)/k · size
    collective-permute  1.0 · size

where k = replica-group size parsed from the op.  These are per-device bytes
crossing links, which is what the ICI term wants.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?|replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of 'bf16[8,128]' or a tuple '(bf16[...], u32[...])'."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    """Replica-group size for a collective op line."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:  # iota format: [ngroups, group_size]
        return max(1, int(m.group(2)))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"source_target_pairs=\{", line)
    if m:
        return 2
    return 2


@dataclasses.dataclass
class CollectiveStats:
    by_kind: dict
    total_bytes: float          # effective per-device bytes over links
    raw_bytes: float            # sum of result sizes (no traffic weighting)
    count: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_kind: dict[str, dict] = {}
    total = 0.0
    raw = 0.0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double counting async start/done pairs
        size = _shape_bytes(shape_str)
        k = _group_size(line)
        if kind == "all-reduce":
            eff = 2.0 * (k - 1) / k * size
        elif kind == "all-gather":
            eff = (k - 1) / k * size
        elif kind == "reduce-scatter":
            eff = (k - 1) * size        # operand = result·k ⇒ (k-1)/k·(k·size)
        elif kind == "all-to-all":
            eff = (k - 1) / k * size
        else:  # collective-permute
            eff = size
        d = by_kind.setdefault(kind, {"count": 0, "bytes": 0.0, "eff_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += size
        d["eff_bytes"] += eff
        total += eff
        raw += size
        count += 1
    return CollectiveStats(by_kind, total, raw, count)


_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str | None]:
    """Split HLO text into {computation_name: [lines]}; returns entry name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "{" in line and ("->" in line or line.startswith(("ENTRY", "%"))):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _line_collective(line: str):
    m = _COLLECTIVE_RE.match(line)
    if not m or "-done(" in line:
        return None
    size = _shape_bytes(m.group(1))
    k = _group_size(line)
    kind = m.group(2)
    if kind == "all-reduce":
        eff = 2.0 * (k - 1) / k * size
    elif kind == "all-gather":
        eff = (k - 1) / k * size
    elif kind == "reduce-scatter":
        eff = (k - 1) * size
    elif kind == "all-to-all":
        eff = (k - 1) / k * size
    else:
        eff = size
    return kind, size, eff


def parse_collectives_corrected(hlo_text: str) -> CollectiveStats:
    """Collective stats with while-loop trip-count multipliers.

    XLA annotates while ops with backend_config known_trip_count; we walk the
    call graph from ENTRY multiplying body computations by their trip counts
    (fusions/calls/conditional branches get ×1), so per-layer collectives
    inside lax.scan are charged reps× — matching runtime behaviour.
    """
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return parse_collectives(hlo_text)

    # per-computation direct collectives and references
    direct: dict[str, list] = {}
    refs: dict[str, list[tuple[str, float]]] = {}
    for name, lines in comps.items():
        direct[name] = []
        refs[name] = []
        for line in lines:
            got = _line_collective(line)
            if got:
                direct[name].append(got)
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                refs[name].append((wm.group(2), trip))
                refs[name].append((wm.group(1), trip))
                continue
            bm = _BRANCH_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    refs[name].append((b.strip().lstrip("%"), 1.0))
            for cm in _CALL_RE.finditer(line):
                refs[name].append((cm.group(1), 1.0))

    # propagate multipliers (call graph is a DAG in HLO)
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for child, factor in refs.get(name, []):
            visit(child, m * factor)

    visit(entry, 1.0)

    by_kind: dict[str, dict] = {}
    total = raw = 0.0
    count = 0
    for name, items in direct.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for kind, size, eff in items:
            d = by_kind.setdefault(kind, {"count": 0, "bytes": 0.0, "eff_bytes": 0.0})
            d["count"] += int(m)
            d["bytes"] += m * size
            d["eff_bytes"] += m * eff
            total += m * eff
            raw += m * size
            count += int(m)
    return CollectiveStats(by_kind, total, raw, count)


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   model_flops: float = 0.0) -> Roofline:
    comp = flops / (chips * PEAK_FLOPS_BF16)
    mem = bytes_accessed / (chips * HBM_BW)
    coll = collective_bytes / (chips * ICI_BW)
    dom = max((("compute", comp), ("memory", mem), ("collective", coll)),
              key=lambda kv: kv[1])[0]
    return Roofline(flops, bytes_accessed, collective_bytes, chips,
                    comp, mem, coll, dom, model_flops,
                    (model_flops / flops) if flops else 0.0)


def model_flops_estimate(n_params_active: int, tokens: int, kind: str,
                         zo: bool = True) -> float:
    """'Useful' FLOPs convention: forward 2·N·D; FO train 6·N·D; ZO train
    4·N·D (two forwards, no backward); decode/prefill 2·N·D."""
    if kind == "train":
        return (4.0 if zo else 6.0) * n_params_active * tokens
    return 2.0 * n_params_active * tokens


def fmt_seconds(s: float) -> str:
    if s < 1e-6:
        return f"{s * 1e9:.1f}ns"
    if s < 1e-3:
        return f"{s * 1e6:.1f}us"
    if s < 1.0:
        return f"{s * 1e3:.2f}ms"
    return f"{s:.3f}s"
