"""Public jit'd wrappers for the Pallas kernels.

``use_pallas`` defaults to False off-TPU: the dry-run path (CPU backend with
512 placeholder devices) and the simulator use the pure-jnp references in
ref.py; on real TPU hardware the Pallas implementations take over.  Tests
exercise the kernels in interpret mode against the oracles across
shape/dtype sweeps.
"""
from __future__ import annotations

import jax

from repro.kernels import ref as _ref
from repro.kernels import rank1_matmul as _r1
from repro.kernels import selective_scan as _scan
from repro.kernels import subcge_apply as _apply


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def subcge_apply(W, U, A, V, *, use_pallas: bool | None = None,
                 interpret: bool = False):
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas or interpret:
        return _apply.subcge_apply(W, U, A, V, interpret=interpret)
    return _ref.subcge_apply(W, U, A, V)


def rank1_matmul(x, W, u, v, s, *, use_pallas: bool | None = None,
                 interpret: bool = False):
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas or interpret:
        return _r1.rank1_matmul(x, W, u, v, s, interpret=interpret)
    return _ref.rank1_matmul(x, W, u, v, s)


def selective_scan(a, bx, c, h0, *, use_pallas: bool | None = None,
                   interpret: bool = False):
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas or interpret:
        return _scan.selective_scan(a, bx, c, h0, interpret=interpret)
    return _ref.selective_scan(a, bx, c, h0)
