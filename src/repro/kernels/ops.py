"""Public dispatch layer for the Pallas kernel suite.

Every hot-path op has two real implementations behind the one
``kernel_backend`` knob (legal values: :data:`repro.configs.base.KERNEL_BACKENDS`):

* ``"jnp"``       — the pure-jnp oracles in :mod:`repro.kernels.ref`.  This is
  bitwise the pre-kernel training stack (the golden-parity suite pins it) and
  the resolved default off-TPU.
* ``"pallas"``    — the compiled Pallas TPU lowerings.
* ``"interpret"`` — the *same* Pallas kernels through the Pallas interpreter,
  so CI exercises the real kernel bodies on CPU.
* ``"auto"``      — resolve once per process: ``pallas`` on TPU, ``jnp``
  elsewhere.

Backend resolution is explicit and cached: ``"auto"`` is resolved exactly once
(:func:`_resolve_auto` is memoized) instead of re-sniffing ``jax.default_backend()``
on every call, and the backend any jitted caller sees is a plain Python string
captured at trace time.  :func:`set_default_backend` changes the process
default for traces created *afterwards* — per-run code (the dtrain method
plugins, ``PodConfig``) threads the knob explicitly through fresh per-run jit
closures, so two runs in one process can never share a stale trace.

The kernel modules are imported lazily inside the dispatchers (they import
:func:`_tile` from here, and the jnp path should not pay for Pallas imports).
"""
from __future__ import annotations

import contextlib
import functools

import jax

from repro.configs.base import KERNEL_BACKENDS
from repro.kernels import ref as _ref

_default_backend = "auto"


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=None)
def _resolve_auto() -> str:
    """What ``"auto"`` means on this process — computed once, then frozen, so
    jitted callers cannot silently flip paths between traces."""
    return "pallas" if on_tpu() else "jnp"


def set_default_backend(backend: str) -> str:
    """Set the process-default backend; returns the previous value.

    Only affects traces created after the call — already-compiled jit caches
    keep the backend they captured.
    """
    global _default_backend
    if backend not in KERNEL_BACKENDS:
        raise ValueError(f"kernel_backend must be one of {KERNEL_BACKENDS}, "
                         f"got {backend!r}")
    prev, _default_backend = _default_backend, backend
    return prev


def get_default_backend() -> str:
    return _default_backend


@contextlib.contextmanager
def default_backend(backend: str):
    """Scoped :func:`set_default_backend` (tests, benchmarks)."""
    prev = set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(prev)


def resolve_backend(backend: str | None = None) -> str:
    """Map a knob value (or None = process default) to a concrete backend:
    one of ``"jnp" | "pallas" | "interpret"``."""
    if backend is None:
        backend = _default_backend  # sfcheck: noqa[SF002] -- the ONE sanctioned trace-time read (DESIGN.md §7/§8): backend choice is captured per trace by design, set_default_backend/default_backend document that live traces keep their backend; every per-run path passes the knob explicitly
    if backend not in KERNEL_BACKENDS:
        raise ValueError(f"kernel_backend must be one of {KERNEL_BACKENDS}, "
                         f"got {backend!r}")
    return _resolve_auto() if backend == "auto" else backend


# ---------------------------------------------------------------------------
# tiling
# ---------------------------------------------------------------------------

def _tile(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``target``, preferring
    lane-aligned (multiple-of-128) divisors.

    Among all admissible divisors a multiple of 128 wins even when a larger
    unaligned divisor exists (MXU/VPU lanes are 128 wide); with no aligned
    divisor the genuinely largest one is returned — e.g.
    ``_tile(320, 256) == 160`` (not 80), ``_tile(896, 256) == 128`` (128
    divides 896; the larger 224 does not align).
    """
    best, best_aligned = 1, 0
    for t in range(1, min(target, dim) + 1):
        if dim % t == 0:
            best = t
            if t % 128 == 0:
                best_aligned = t
    return best_aligned or best


# ---------------------------------------------------------------------------
# dispatchers
# ---------------------------------------------------------------------------

def subcge_apply(W, U, A, V, *, backend: str | None = None):
    """W (*B,n,m) + U (n,r) A (*B,r,r) V (m,r)^T — the SubCGE replay."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.subcge_apply(W, U, A, V)
    from repro.kernels import subcge_apply as _apply
    return _apply.subcge_apply(W, U, A, V, interpret=(b == "interpret"))


def subcge_apply_epochs(W, U, A, V, *, backend: str | None = None):
    """W (*B,n,m) + Σ_e U (E,n,r)[e] A (E,*B,r,r)[e] V (E,m,r)[e]^T — the
    epoch-grouped padded replay layout (one fused visit of W for all τ-epochs
    present in a flood payload batch)."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.subcge_apply_epochs(W, U, A, V)
    from repro.kernels import subcge_apply as _apply
    return _apply.subcge_apply_epochs(W, U, A, V, interpret=(b == "interpret"))


def subcge_delta(U, A, V, dtype, *, backend: str | None = None):
    """U A V^T alone (no base weight), in ``dtype``.  Kernel backends stream
    a zero W through the fused-apply kernel (delta extraction is not a hot
    path; it exists so every A-application shares one lowering)."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.subcge_delta(U, A, V, dtype)
    import jax.numpy as jnp
    from repro.kernels import subcge_apply as _apply
    zero = jnp.zeros(A.shape[:-2] + (U.shape[-2], V.shape[-2]), dtype)
    return _apply.subcge_apply(zero, U, A, V, interpret=(b == "interpret"))


def rank1_matmul(x, W, u, v, s, *, backend: str | None = None):
    """x (M,K) @ (W (K,N) + s·u v^T) — the fused ZO dual forward matmul."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.rank1_matmul(x, W, u, v, s)
    from repro.kernels import rank1_matmul as _r1
    return _r1.rank1_matmul(x, W, u, v, s, interpret=(b == "interpret"))


def rank1_matmul_t(x, W, u, v, s, *, backend: str | None = None):
    """x (M,N) @ (W (O,N) + s·u v^T)^T — tied-embedding logits."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.rank1_matmul_t(x, W, u, v, s)
    from repro.kernels import rank1_matmul as _r1
    return _r1.rank1_matmul_t(x, W, u, v, s, interpret=(b == "interpret"))


def rank1_matmul_expert(x, W, u, v, s, *, backend: str | None = None):
    """x (E,C,n) @ (W (E,n,m) + s·u[:,e] v[:,e]^T) — per-expert rank-1
    perturbations, u (n,E), v (m,E)."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.rank1_matmul_expert(x, W, u, v, s)
    from repro.kernels import rank1_matmul as _r1
    return _r1.rank1_matmul_expert(x, W, u, v, s, interpret=(b == "interpret"))


def selective_scan(a, bx, c, h0, *, backend: str | None = None):
    """Blocked Mamba selective scan (see kernels/selective_scan.py)."""
    b = resolve_backend(backend)
    if b == "jnp":
        return _ref.selective_scan(a, bx, c, h0)
    from repro.kernels import selective_scan as _scan
    return _scan.selective_scan(a, bx, c, h0, interpret=(b == "interpret"))
