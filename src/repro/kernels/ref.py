"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def subcge_apply(W: jax.Array, U: jax.Array, A: jax.Array,
                 V: jax.Array) -> jax.Array:
    """W + U A V^T, batched over leading instance dims of W/A.
    W (*B, n, m), U (n, r), A (*B, r, r), V (m, r)."""
    delta = jnp.einsum("nr,...rs,ms->...nm", U.astype(jnp.float32),
                       A.astype(jnp.float32), V.astype(jnp.float32))
    return (W.astype(jnp.float32) + delta).astype(W.dtype)


def rank1_matmul(x: jax.Array, W: jax.Array, u: jax.Array, v: jax.Array,
                 s) -> jax.Array:
    """x @ (W + s·u v^T) = x W + s (x·u) v^T.   x (M,K) W (K,N) u (K,) v (N,)."""
    y = jnp.dot(x.astype(jnp.float32), W.astype(jnp.float32))
    xu = jnp.dot(x.astype(jnp.float32), u.astype(jnp.float32))
    y = y + jnp.asarray(s, jnp.float32) * xu[:, None] * v.astype(jnp.float32)[None, :]
    return y.astype(x.dtype)


def selective_scan(a: jax.Array, bx: jax.Array, c: jax.Array,
                   h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequential reference: h_t = a_t ⊙ h_{t-1} + bx_t;  y_t = Σ_n h_t·c_t.
    a/bx (B,T,D,N), c (B,T,N), h0 (B,D,N) -> y (B,T,D), h_last (B,D,N)."""
    def step(h, inp):
        at, bt, ct = inp
        h = at * h + bt
        return h, jnp.einsum("bdn,bn->bd", h, ct)

    hT, y = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
         jnp.moveaxis(bx, 1, 0).astype(jnp.float32),
         jnp.moveaxis(c, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(y, 0, 1), hT
