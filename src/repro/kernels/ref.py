"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def subcge_apply(W: jax.Array, U: jax.Array, A: jax.Array,
                 V: jax.Array) -> jax.Array:
    """W + U A V^T, batched over leading instance dims of W/A.
    W (*B, n, m), U (n, r), A (*B, r, r), V (m, r).

    The delta accumulates in f32 but the add happens in W's dtype — this is
    bitwise the pre-kernel training stack (``subcge.apply_A``), which the
    golden-parity suite pins; the Pallas kernels instead add in f32 before
    the final cast (tolerance-level difference for sub-f32 weights).
    """
    delta = jnp.einsum("nr,...rs,ms->...nm", U.astype(jnp.float32),
                       A.astype(jnp.float32), V.astype(jnp.float32))
    return W + delta.astype(W.dtype)


def subcge_delta(U: jax.Array, A: jax.Array, V: jax.Array, dtype) -> jax.Array:
    """U A V^T alone (no base weight).  U (n, r), A (*B, r, r), V (m, r)."""
    return jnp.einsum("nr,...rs,ms->...nm", U.astype(jnp.float32),
                      A.astype(jnp.float32), V.astype(jnp.float32)).astype(dtype)


def subcge_apply_epochs(W: jax.Array, U: jax.Array, A: jax.Array,
                        V: jax.Array) -> jax.Array:
    """W + Σ_e U[e] A[e] V[e]^T — the epoch-grouped replay layout.
    W (*B, n, m), U (E, n, r), A (E, *B, r, r), V (E, m, r)."""
    delta = jnp.einsum("enr,e...rs,ems->...nm", U.astype(jnp.float32),
                       A.astype(jnp.float32), V.astype(jnp.float32))
    return W + delta.astype(W.dtype)


def rank1_matmul(x: jax.Array, W: jax.Array, u: jax.Array, v: jax.Array,
                 s) -> jax.Array:
    """x @ (W + s·u v^T) = x W + s (x·u) v^T.   x (M,K) W (K,N) u (K,) v (N,)."""
    y = jnp.dot(x.astype(jnp.float32), W.astype(jnp.float32))
    xu = jnp.dot(x.astype(jnp.float32), u.astype(jnp.float32))
    y = y + jnp.asarray(s, jnp.float32) * xu[:, None] * v.astype(jnp.float32)[None, :]
    return y.astype(x.dtype)


def rank1_matmul_t(x: jax.Array, W: jax.Array, u: jax.Array, v: jax.Array,
                   s) -> jax.Array:
    """x @ (W + s·u v^T)^T = x W^T + s (x·v) u^T — tied-embedding logits.
    x (M,N) W (O,N) u (O,) v (N,) -> (M,O)."""
    y = jnp.dot(x.astype(jnp.float32), W.astype(jnp.float32).T)
    xv = jnp.dot(x.astype(jnp.float32), v.astype(jnp.float32))
    y = y + jnp.asarray(s, jnp.float32) * xv[:, None] * u.astype(jnp.float32)[None, :]
    return y.astype(x.dtype)


def rank1_matmul_expert(x: jax.Array, W: jax.Array, u: jax.Array,
                        v: jax.Array, s) -> jax.Array:
    """Per-expert rank-1-perturbed batched matmul.
    x (E,C,n), W (E,n,m), u (n,E), v (m,E):
    y[e] = x[e] @ W[e] + s·(x[e]·u[:,e]) v[:,e]^T."""
    xf = x.astype(jnp.float32)
    y = jnp.einsum("ecn,enm->ecm", xf, W.astype(jnp.float32))
    xu = jnp.einsum("ecn,ne->ec", xf, u.astype(jnp.float32))
    y = y + (jnp.asarray(s, jnp.float32) * xu[..., None]
             * v.astype(jnp.float32).T[:, None, :])
    return y.astype(x.dtype)


def selective_scan(a: jax.Array, bx: jax.Array, c: jax.Array,
                   h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequential reference: h_t = a_t ⊙ h_{t-1} + bx_t;  y_t = Σ_n h_t·c_t.
    a/bx (B,T,D,N), c (B,T,N), h0 (B,D,N) -> y (B,T,D), h_last (B,D,N)."""
    def step(h, inp):
        at, bt, ct = inp
        h = at * h + bt
        return h, jnp.einsum("bdn,bn->bd", h, ct)

    hT, y = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
         jnp.moveaxis(bx, 1, 0).astype(jnp.float32),
         jnp.moveaxis(c, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(y, 0, 1), hT
