# Pallas TPU kernels for the paper's compute hot-spots (validated in
# interpret mode against the jnp oracles in ref.py; selected on TPU by
# ops.py):
#   subcge_apply   — W += U A V^T, the SubCGE aggregated update (App. A)
#   rank1_matmul   — y = xW + s(xu)v^T, the fused ±ε client forward
#   selective_scan — blocked Mamba recurrence (ssm/hybrid archs)
from repro.kernels import ops, ref  # noqa: F401
