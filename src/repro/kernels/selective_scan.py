"""Pallas TPU kernel: blocked Mamba selective scan.

    h_t = a_t ⊙ h_{t-1} + b_t,      y_t = Σ_n h_t[d,n] · c_t[n]

GPU Mamba kernels lean on warp-level shuffles; the TPU-native shape is a
*blocked sequential* scan: grid (B, D/bd, T/bt) with the time axis as the
innermost ("arbitrary"/sequential) dimension, the running state h (bd, N)
resident in a VMEM scratch that persists across sequential grid steps, and
the within-block recurrence unrolled over bt VPU steps on (bd, N) panels.
This keeps HBM traffic at 1× read of (a, b, c) + 1× write of y — the same
roofline floor as attention-free inference — with zero recomputation (the
pure-JAX path in models/layers.py pays an associative-scan's extra state
materialization instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import _tile

# jax < 0.5 ships this as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, h_ref, *, bt, nt):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t].astype(jnp.float32)       # (bd, N)
        b_t = b_ref[0, t].astype(jnp.float32)       # (bd, N)
        c_t = c_ref[0, t].astype(jnp.float32)       # (1, N)
        h = a_t * h + b_t
        y_ref[0, t] = jnp.sum(h * c_t, axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, h_ref[...])
    h_ref[...] = h

    @pl.when(pl.program_id(2) == nt - 1)
    def _done():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bt", "interpret"))
def selective_scan(a: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array,
                   *, bd: int = 128, bt: int = 128,
                   interpret: bool = False):
    """a/bx (B,T,D,N) f32-castable, c (B,T,N), h0 (B,D,N)
    -> y (B,T,D) f32, h_last (B,D,N) f32."""
    B, T, D, N = a.shape
    bd = _tile(D, bd)
    bt = _tile(T, bt)
    nt = T // bt
    grid = (B, D // bd, nt)

    # layout: time-major blocks of (bt, bd, N)
    am = jnp.moveaxis(a, 1, 1)  # already (B,T,D,N)

    y, h_last = pl.pallas_call(
        functools.partial(_kernel, bt=bt, nt=nt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd, N), lambda b, d, t: (b, t, d, 0)),
            pl.BlockSpec((1, bt, bd, N), lambda b, d, t: (b, t, d, 0)),
            pl.BlockSpec((1, bt, 1, N), lambda b, d, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bd, N), lambda b, d, t: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bd, N), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, T, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(am, bx, c.reshape(B, T, 1, N), h0)
    return y, h_last
