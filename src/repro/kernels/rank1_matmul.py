"""Pallas TPU kernel: fused rank-1-perturbed matmul  y = x W + s·(x u) v^T.

The ZO dual forward evaluates every client at W ± ε·u v^T.  Materializing the
perturbed weight would double W traffic (read + write of an n×m temp); this
kernel computes the rank-1 epilogue inside the matmul's k-loop: the extra
work per (bm × bk) x-tile is one (bk→1) dot for x·u, and the epilogue adds
s·(xu)·v to the accumulator on the final k step.  W is streamed exactly once,
same as an unperturbed matmul — the perturbation is compute-free at the
memory roofline.

Grid: (M/bm, N/bn, K/bk), k innermost/sequential; f32 accumulators in VMEM
scratch (acc for xW, xu for the rank-1 partial).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, w_ref, u_ref, v_ref, s_ref, o_ref, acc_ref, xu_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xu_ref[...] = jnp.zeros_like(xu_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xu_ref[...] += jnp.dot(x, u_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        s = s_ref[0, 0]
        o_ref[...] = (acc_ref[...]
                      + s * xu_ref[...] * v_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def _tile(dim: int, target: int) -> int:
    t = min(target, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def rank1_matmul(x: jax.Array, W: jax.Array, u: jax.Array, v: jax.Array,
                 s, *, bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = False) -> jax.Array:
    """x (M,K) @ (W (K,N) + s·u (K,) v (N,)^T) -> (M,N)."""
    M, K = x.shape
    K2, N = W.shape
    assert K == K2 and u.shape == (K,) and v.shape == (N,)
    bm = _tile(M, bm)
    bn = _tile(N, bn)
    bk = _tile(K, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),       # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),       # W
            pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),        # u column
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),        # v row
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),         # s
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, W, u.reshape(K, 1), v.reshape(1, N),
      jnp.asarray(s, jnp.float32).reshape(1, 1))
    return out
