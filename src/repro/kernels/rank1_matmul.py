"""Pallas TPU kernel: fused rank-1-perturbed matmul  y = x W + s·(x u) v^T.

The ZO dual forward evaluates every client at W ± ε·u v^T.  Materializing the
perturbed weight would double W traffic (read + write of an n×m temp); this
kernel computes the rank-1 epilogue inside the matmul's k-loop: the extra
work per (bm × bk) x-tile is one (bk→1) dot for x·u, and the epilogue adds
s·(xu)·v to the accumulator on the final k step.  W is streamed exactly once,
same as an unperturbed matmul — the perturbation is compute-free at the
memory roofline.

Grid: (M/bm, N/bn, K/bk), k innermost/sequential; f32 accumulators in VMEM
scratch (acc for xW, xu for the rank-1 partial).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import _tile

# jax < 0.5 ships this as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, w_ref, u_ref, v_ref, s_ref, o_ref, acc_ref, xu_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xu_ref[...] = jnp.zeros_like(xu_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xu_ref[...] += jnp.dot(x, u_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        s = s_ref[0, 0]
        o_ref[...] = (acc_ref[...]
                      + s * xu_ref[...] * v_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def rank1_matmul(x: jax.Array, W: jax.Array, u: jax.Array, v: jax.Array,
                 s, *, bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = False) -> jax.Array:
    """x (M,K) @ (W (K,N) + s·u (K,) v (N,)^T) -> (M,N)."""
    M, K = x.shape
    K2, N = W.shape
    assert K == K2 and u.shape == (K,) and v.shape == (N,)
    bm = _tile(M, bm)
    bn = _tile(N, bn)
    bk = _tile(K, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),       # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),       # W
            pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),        # u column
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),        # v row
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),         # s
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, W, u.reshape(K, 1), v.reshape(1, N),
      jnp.asarray(s, jnp.float32).reshape(1, 1))
    return out


def _kernel_t(x_ref, w_ref, v_ref, u_ref, s_ref, o_ref, acc_ref, xv_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xv_ref[...] = jnp.zeros_like(xv_ref)

    x = x_ref[...]
    # x (bm, bk) · W (bo, bk)^T contracted on the shared bk axis — the MXU
    # takes the transposed operand natively, no VMEM transpose materialized
    acc_ref[...] += jax.lax.dot_general(
        x, w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xv_ref[...] += jnp.dot(x, v_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        s = s_ref[0, 0]
        o_ref[...] = (acc_ref[...]
                      + s * xv_ref[...] * u_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bo", "bk", "interpret"))
def rank1_matmul_t(x: jax.Array, W: jax.Array, u: jax.Array, v: jax.Array,
                   s, *, bm: int = 256, bo: int = 256, bk: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x (M,N) @ (W (O,N) + s·u (O,) v (N,)^T)^T -> (M,O).

    The tied-embedding logits matmul: W is stored output-major (vocab, d) and
    must not be transposed in HBM — the k-loop contracts x and W on their
    shared N axis, with the rank-1 epilogue s·(x·v)·u^T folded into the final
    k step exactly as in :func:`rank1_matmul`.
    """
    M, N = x.shape
    O, N2 = W.shape
    assert N == N2 and u.shape == (O,) and v.shape == (N,)
    bm = _tile(M, bm)
    bo = _tile(O, bo)
    bk = _tile(N, bk)
    nk = N // bk
    grid = (M // bm, O // bo, nk)

    out = pl.pallas_call(
        functools.partial(_kernel_t, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),       # x
            pl.BlockSpec((bo, bk), lambda i, j, k: (j, k)),       # W
            pl.BlockSpec((bk, 1), lambda i, j, k: (k, 0)),        # v column
            pl.BlockSpec((1, bo), lambda i, j, k: (0, j)),        # u row
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),         # s
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, O), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bo), jnp.float32),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, W, v.reshape(N, 1), u.reshape(1, O),
      jnp.asarray(s, jnp.float32).reshape(1, 1))
    return out


def _kernel_expert(x_ref, w_ref, u_ref, v_ref, s_ref, o_ref, acc_ref, xu_ref,
                   *, nk):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xu_ref[...] = jnp.zeros_like(xu_ref)

    x = x_ref[0]
    acc_ref[...] += jnp.dot(x, w_ref[0], preferred_element_type=jnp.float32)
    xu_ref[...] += jnp.dot(x, u_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == nk - 1)
    def _done():
        s = s_ref[0, 0]
        o_ref[0] = (acc_ref[...]
                    + s * xu_ref[...] * v_ref[...].astype(jnp.float32).T
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bn", "bk", "interpret"))
def rank1_matmul_expert(x: jax.Array, W: jax.Array, u: jax.Array,
                        v: jax.Array, s, *, bc: int = 256, bn: int = 256,
                        bk: int = 512, interpret: bool = False) -> jax.Array:
    """Batched per-expert rank-1-perturbed matmul:
    x (E,C,n), W (E,n,m), u (n,E), v (m,E) ->
    y[e] = x[e] @ W[e] + s·(x[e]·u[:,e]) v[:,e]^T.

    Experts ride the leading (parallel) grid axis like the instance dim of
    ``subcge_apply``; each expert's u/v columns are sliced straight out of
    the (dim, E) coordinate panels, and the k-loop epilogue is per-expert.
    """
    E, C, n = x.shape
    E2, n2, m = W.shape
    assert E == E2 and n == n2 and u.shape == (n, E) and v.shape == (m, E)
    bc = _tile(C, bc)
    bn = _tile(m, bn)
    bk = _tile(n, bk)
    nk = n // bk
    grid = (E, C // bc, m // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel_expert, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e, i, j, k: (e, i, k)),   # x
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),   # W
            pl.BlockSpec((bk, 1), lambda e, i, j, k: (k, e)),          # u col
            pl.BlockSpec((bn, 1), lambda e, i, j, k: (j, e)),          # v col
            pl.BlockSpec((1, 1), lambda e, i, j, k: (0, 0)),           # s
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, m), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32),
                        pltpu.VMEM((bc, 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, W, u, v, jnp.asarray(s, jnp.float32).reshape(1, 1))
    return out
