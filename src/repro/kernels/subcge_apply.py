"""Pallas TPU kernel: fused SubCGE weight update  W ← W + U A V^T.

This is the paper's hot spot (Appendix A / Fig. 5): applying the aggregated
coefficient matrix A to every 2D weight.  On GPU the paper's win came from
replacing per-message axpys with batched GEMMs; on TPU we go further and
stream W through VMEM exactly once, fusing both thin GEMMs into the tile
visit — arithmetic intensity per W-tile is 2·r·(bn+bm) FLOPs at (bn·bm)
bytes, so the kernel is HBM-bandwidth-bound at precisely 1× W traffic, the
roofline floor for any update touching all of W.

Grid: (instances, n/bn, m/bm); instance dims (scan periods, experts) are
collapsed into the leading grid axis.  A (r×r per instance) and the U/V
column panels ride along in VMEM; MXU-aligned tiles (multiples of
128 where the weight allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import _tile


def _kernel(w_ref, u_ref, v_ref, a_ref, o_ref):
    ua = jnp.dot(u_ref[...].astype(jnp.float32), a_ref[0],
                 preferred_element_type=jnp.float32)          # (bn, r)
    delta = jnp.dot(ua, v_ref[...].astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)       # (bn, bm)
    o_ref[0] = (w_ref[0].astype(jnp.float32) + delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def subcge_apply(W: jax.Array, U: jax.Array, A: jax.Array, V: jax.Array,
                 *, bn: int = 256, bm: int = 256,
                 interpret: bool = False) -> jax.Array:
    """W (*B, n, m) + U (n, r) @ A (*B, r, r) @ V (m, r)^T."""
    batch = W.shape[:-2]
    n, m = W.shape[-2:]
    r = U.shape[-1]
    nb = 1
    for b in batch:
        nb *= b
    Wf = W.reshape(nb, n, m)
    Af = A.reshape(nb, r, r).astype(jnp.float32)

    bn = _tile(n, bn)
    bm = _tile(m, bm)
    grid = (nb, n // bn, m // bm)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bm), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((bn, r), lambda b, i, j: (i, 0)),
            pl.BlockSpec((bm, r), lambda b, i, j: (j, 0)),
            pl.BlockSpec((1, r, r), lambda b, i, j: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct(Wf.shape, W.dtype),
        interpret=interpret,
    )(Wf, U, V, Af)
    return out.reshape(W.shape)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def subcge_apply_epochs(W: jax.Array, U: jax.Array, A: jax.Array,
                        V: jax.Array, *, bn: int = 256, bm: int = 256,
                        interpret: bool = False) -> jax.Array:
    """W (*B,n,m) + Σ_e U (E,n,r)[e] @ A (E,*B,r,r)[e] @ V (E,m,r)[e]^T.

    The epoch-grouped replay of delayed-flooding payloads: messages whose
    staleness crosses τ-refresh boundaries partition into E subspace epochs,
    each with its own (U_e, V_e, A_e).  Rather than streaming W once per
    epoch, the epochs fold into a single rank-(E·r) visit:

        Σ_e U_e A_e V_e^T  =  [U_1 … U_E] · blockdiag(A_1 … A_E) · [V_1 … V_E]^T

    so the fused-apply kernel runs unchanged at rank E·r — still exactly one
    HBM read+write of W.  E and r are small (E is pow2-bucketed by
    ``subcge.epoch_slots``; the block-diagonal is (E·r)² f32, VMEM-trivial).
    """
    E, n, r = U.shape
    m = V.shape[1]
    batch = W.shape[:-2]
    nb = 1
    for b in batch:
        nb *= b
    if E == 1:
        return subcge_apply(W, U[0], A[0], V[0], bn=bn, bm=bm,
                            interpret=interpret)
    Uc = jnp.moveaxis(U, 0, 1).reshape(n, E * r)
    Vc = jnp.moveaxis(V, 0, 1).reshape(m, E * r)
    Af = A.reshape(E, nb, r, r).astype(jnp.float32)
    blk = jnp.zeros((nb, E * r, E * r), jnp.float32)
    for e in range(E):
        blk = blk.at[:, e * r:(e + 1) * r, e * r:(e + 1) * r].set(Af[e])
    out = subcge_apply(W.reshape(nb, n, m), Uc, blk, Vc, bn=bn, bm=bm,
                       interpret=interpret)
    return out.reshape(W.shape)
