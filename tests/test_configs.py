"""Config registry: parameter counts must land on the billed model sizes."""
import pytest

from repro.configs import archs
from repro.configs.base import INPUT_SHAPES
from repro.models import transformer as tf

# (arch, expected params, rel tolerance).  Expectations from the source
# papers/model cards cited in each config.
EXPECTED = {
    "jamba-1.5-large-398b": (398e9, 0.10),
    "qwen1.5-0.5b": (0.46e9, 0.15),
    "tinyllama-1.1b": (1.1e9, 0.10),
    "qwen2-72b": (72.7e9, 0.10),
    "kimi-k2-1t-a32b": (1.0e12, 0.10),
    "musicgen-medium": (1.5e9, 0.20),
    "internvl2-26b": (20e9, 0.15),     # LM backbone only; ViT-6B stubbed
    "falcon-mamba-7b": (7.3e9, 0.10),
    "gemma3-1b": (1.0e9, 0.10),
    "deepseek-v2-236b": (236e9, 0.10),
}


@pytest.mark.parametrize("name", archs.ASSIGNED)
def test_param_count_matches_billed_size(name):
    want, tol = EXPECTED[name]
    got = tf.count_params(archs.get(name))
    assert abs(got - want) / want < tol, f"{name}: {got/1e9:.2f}B vs {want/1e9:.2f}B"


def test_all_assigned_archs_registered():
    assert len(archs.ASSIGNED) == 10
    for a in archs.ASSIGNED:
        cfg = archs.get(a)
        assert cfg.name == a
        assert cfg.source, f"{a} must cite its source"


def test_input_shapes_exact():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq, s["long_500k"].global_batch) == (524288, 1)


def test_layer_counts():
    for name, n in [("jamba-1.5-large-398b", 72), ("qwen2-72b", 80),
                    ("kimi-k2-1t-a32b", 61), ("deepseek-v2-236b", 60),
                    ("falcon-mamba-7b", 64), ("gemma3-1b", 26)]:
        assert archs.get(name).n_layers == n


def test_jamba_interleave_ratio():
    cfg = archs.get("jamba-1.5-large-398b")
    slots = cfg.layer_cfgs()
    n_attn = sum(s.mixer == "attn" for s in slots)
    n_mamba = sum(s.mixer == "mamba" for s in slots)
    assert n_mamba == 7 * n_attn                 # 1:7 interleave
    n_moe = sum(s.ffn == "moe" for s in slots)
    assert n_moe == len(slots) // 2              # MoE every other layer


def test_gemma3_local_global_ratio():
    slots = archs.get("gemma3-1b").layer_cfgs()
    local = sum(s.attn.window is not None for s in slots)
    glob = sum(s.attn.window is None for s in slots)
    assert (local, glob) == (22, 4)              # 5:1 with remainder local


def test_deepseek_mla_dims():
    a = archs.get("deepseek-v2-236b").layer_cfgs()[0].attn
    assert a.is_mla and a.kv_lora == 512 and a.q_lora == 1536
    assert a.rope_head_dim == 64 and a.n_heads == 128


def test_reduced_variants_are_small_but_same_family():
    for name in archs.ASSIGNED:
        cfg = archs.get(name)
        red = archs.reduced(cfg)
        assert red.n_layers <= 2
        assert red.d_model <= 512
        assert red.family == cfg.family
        mixers = {s.mixer for s in cfg.layer_cfgs()}
        red_mixers = {s.mixer for s in red.layer_cfgs()}
        assert red_mixers <= mixers
        if any(s.ffn == "moe" for s in cfg.layer_cfgs()):
            moe_slots = [s for s in red.layer_cfgs() if s.ffn == "moe"]
            assert moe_slots and all(s.moe.n_experts <= 4 for s in moe_slots)
