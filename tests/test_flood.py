"""Flooding protocol properties (paper §3.3): exactly-once delivery, full
coverage within diameter rounds, fixed coefficients, delayed-flooding
staleness bounds, byte accounting."""
import networkx as nx
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import flood
from repro.core.messages import Message
from repro.topology import graphs


def _inject_all(net, step=0):
    for i in range(net.n):
        net.inject(i, Message(seed=1000 + i, coef=0.5, origin=i, step=step))


@pytest.mark.parametrize("topo,n", [("ring", 8), ("ring", 16),
                                    ("meshgrid", 16), ("star", 9),
                                    ("complete", 6), ("torus", 16)])
def test_full_flood_coverage_exactly_once(topo, n):
    net = flood.FloodNetwork(graphs.make(topo, n))
    _inject_all(net)
    fresh = net.full_flood()
    for i in range(net.n):
        # every client accepted every other client's message exactly once
        assert len(fresh[i]) == n - 1
        assert len({m.uid for m in fresh[i]}) == n - 1
        assert len(net.states[i].seen) == n
    # coefficients arrive unmodified (flooding never reweights)
    assert all(m.coef == 0.5 for f in fresh for m in f)


@settings(deadline=None, max_examples=15)
@given(st.integers(4, 24), st.integers(0, 10_000))
def test_flood_on_random_connected_graphs(n, seed):
    g = graphs.erdos_renyi(n, p=min(1.0, 2.5 * np.log(n) / n), seed=seed)
    net = flood.FloodNetwork(g)
    _inject_all(net)
    net.rounds(net.diameter)
    for uid in [(i, 0) for i in range(n)]:
        assert net.coverage(uid) == n     # all-gather-equivalent consensus


def test_coverage_grows_with_hops():
    """A message spreads exactly one hop per round on a ring."""
    n = 12
    net = flood.FloodNetwork(graphs.ring(n))
    net.inject(0, Message(seed=1, coef=1.0, origin=0, step=0))
    cov = [net.coverage((0, 0))]
    for _ in range(net.diameter):
        net.round()
        cov.append(net.coverage((0, 0)))
    assert cov[0] == 1
    for k in range(1, len(cov)):
        assert cov[k] == min(n, 1 + 2 * k)   # spreads both directions


def test_delayed_flooding_staleness_bound():
    """With k hops/iteration, a message reaches everyone within ⌈D/k⌉
    iterations (paper §4.5)."""
    n, k = 16, 2
    net = flood.FloodNetwork(graphs.ring(n))
    D = net.diameter
    bound = flood.staleness_bound(D, k)
    net.inject(3, Message(seed=9, coef=1.0, origin=3, step=0))
    iters = 0
    while net.coverage((3, 0)) < n:
        net.rounds(k)
        iters += 1
        assert iters <= bound + 1
    assert iters <= bound


def test_duplicate_suppression():
    net = flood.FloodNetwork(graphs.complete(5))
    _inject_all(net)
    net.full_flood()
    before = {i: len(net.states[i].seen) for i in range(5)}
    fresh = net.rounds(3)            # nothing in flight -> nothing new
    assert all(not f for f in fresh)
    assert {i: len(net.states[i].seen) for i in range(5)} == before


def test_byte_ledger_bounds():
    """Total flood bytes ≤ 2·|E|·messages·MESSAGE_BYTES (each directed edge
    carries each message at most once)."""
    g = graphs.meshgrid(16)
    net = flood.FloodNetwork(g)
    _inject_all(net)
    net.full_flood()
    bound = flood.flood_bytes_per_iteration(g, 16)
    assert 0 < net.ledger.total_bytes <= bound
    assert net.ledger.per_edge == net.ledger.total_bytes / g.number_of_edges()


def test_gossip_sr_history_cost_grows_linearly():
    g = graphs.ring(8)
    b10 = flood.gossip_sr_history_bytes(10, 8, g)
    b20 = flood.gossip_sr_history_bytes(20, 8, g)
    assert b20 == 2 * b10            # O(t·n) per §3.2


def test_disconnected_graph_rejected():
    g = nx.Graph()
    g.add_nodes_from(range(4))
    g.add_edge(0, 1)
    g.add_edge(2, 3)
    with pytest.raises(ValueError):
        flood.FloodNetwork(g)
