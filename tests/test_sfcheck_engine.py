"""Unit tests for the sfcheck whole-program dataflow engine
(`repro.analysis.dataflow`): module naming, cross-module name
resolution, call-graph edges, the called-under-jit and donate-through
fixpoints, local value-flow origins, and the CI output renderers.

Everything runs on in-memory Projects — no filesystem, no jit."""
import ast
import json

from repro.analysis.dataflow import module_name
from repro.analysis.engine import (Diagnostic, Project, render_github,
                                   sarif_report)


def dataflow(sources):
    return Project.from_sources(sources).dataflow()


# ---------------------------------------------------------------------------
# module naming / summaries
# ---------------------------------------------------------------------------

def test_module_name_strips_src_and_init():
    assert module_name(("src", "repro", "core", "flood.py")) \
        == "repro.core.flood"
    assert module_name(("src", "repro", "serve", "__init__.py")) \
        == "repro.serve"
    assert module_name(("tests", "test_x.py")) == "tests.test_x"
    assert module_name(("benchmarks", "bench_y.py")) == "benchmarks.bench_y"


def test_function_qnames_are_module_qualified():
    df = dataflow({"src/repro/core/m.py": (
        "class C:\n"
        "    def meth(self):\n"
        "        pass\n"
        "def top():\n"
        "    def inner():\n"
        "        pass\n")})
    assert "repro.core.m.C.meth" in df.index
    assert "repro.core.m.top" in df.index
    assert "repro.core.m.top.inner" in df.index
    top = df.index["repro.core.m.top"]
    assert df.index["repro.core.m.top.inner"].parent is top


def test_dataflow_is_built_once_and_cached():
    project = Project.from_sources({"src/repro/core/m.py": "x = 1\n"})
    assert project.dataflow() is project.dataflow()


# ---------------------------------------------------------------------------
# name resolution / call graph
# ---------------------------------------------------------------------------

def test_cross_module_import_edge():
    df = dataflow({
        "src/repro/core/a.py": ("from repro.core.b import helper\n"
                                "def f(x):\n"
                                "    return helper(x)\n"),
        "src/repro/core/b.py": ("def helper(x):\n"
                                "    return x\n"),
    })
    f = df.index["repro.core.a.f"]
    assert [t.qname for _, t in f.edges] == ["repro.core.b.helper"]


def test_module_alias_import_edge():
    df = dataflow({
        "src/repro/core/a.py": ("from repro.core import b\n"
                                "def f(x):\n"
                                "    return b.helper(x)\n"),
        "src/repro/core/b.py": ("def helper(x):\n"
                                "    return x\n"),
    })
    f = df.index["repro.core.a.f"]
    assert [t.qname for _, t in f.edges] == ["repro.core.b.helper"]


def test_self_method_and_base_class_resolution():
    df = dataflow({"src/repro/core/m.py": (
        "class Base:\n"
        "    def shared(self):\n"
        "        pass\n"
        "class Sub(Base):\n"
        "    def go(self):\n"
        "        self.shared()\n")})
    go = df.index["repro.core.m.Sub.go"]
    assert [t.qname for _, t in go.edges] == ["repro.core.m.Base.shared"]


def test_unresolvable_call_contributes_no_edge():
    df = dataflow({"src/repro/core/m.py": (
        "def f(obj):\n"
        "    return obj.anything(1)\n")})
    assert df.index["repro.core.m.f"].edges == []


# ---------------------------------------------------------------------------
# called-under-jit fixpoint
# ---------------------------------------------------------------------------

def test_traced_fixpoint_is_transitive_across_modules():
    df = dataflow({
        "src/repro/core/a.py": ("import jax\n"
                                "from repro.core.b import mid\n"
                                "@jax.jit\n"
                                "def f(x):\n"
                                "    return mid(x)\n"),
        "src/repro/core/b.py": ("from repro.core.c import leaf\n"
                                "def mid(x):\n"
                                "    return leaf(x)\n"),
        "src/repro/core/c.py": ("def leaf(x):\n"
                                "    return x\n"
                                "def unrelated(x):\n"
                                "    return x\n"),
    })
    assert "repro.core.a.f" in df.traced
    assert "repro.core.b.mid" in df.traced
    assert "repro.core.c.leaf" in df.traced
    assert "repro.core.c.unrelated" not in df.traced


def test_wrap_form_makes_a_traced_root():
    df = dataflow({"src/repro/core/m.py": (
        "import jax\n"
        "def f(x):\n"
        "    return x\n"
        "g = jax.jit(f, static_argnums=())\n")})
    assert "repro.core.m.f" in df.traced


def test_vmap_ref_edge_traces_the_referenced_function():
    # bare-name references as call arguments (jax.vmap(one)) count
    df = dataflow({"src/repro/core/m.py": (
        "import jax\n"
        "def one(x):\n"
        "    return x\n"
        "@jax.jit\n"
        "def f(xs):\n"
        "    return jax.vmap(one)(xs)\n")})
    assert "repro.core.m.one" in df.traced


def test_nested_defs_of_traced_functions_are_traced():
    df = dataflow({"src/repro/core/m.py": (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    def inner(y):\n"
        "        return y\n"
        "    return inner(x)\n")})
    assert "repro.core.m.f.inner" in df.traced


# ---------------------------------------------------------------------------
# donation facts
# ---------------------------------------------------------------------------

def test_decorator_donation_positions():
    df = dataflow({"src/repro/core/m.py": (
        "import functools\n"
        "import jax\n"
        "@functools.partial(jax.jit, donate_argnums=(0, 2))\n"
        "def upd(p, g, buf):\n"
        "    return p\n")})
    assert df.index["repro.core.m.upd"].donated() == (0, 2)


def test_wrap_and_attr_alias_donation():
    df = dataflow({"src/repro/core/m.py": (
        "import jax\n"
        "def raw(p, g):\n"
        "    return p\n"
        "class M:\n"
        "    def init(self):\n"
        "        self._upd = jax.jit(raw, donate_argnums=(0,))\n")})
    assert df.index["repro.core.m.raw"].donated() == (0,)
    # self._upd resolves to raw through the attribute-alias map
    m_cls = df.project.class_index()["M"][0][1]
    fsum = df.file_summaries()[0]
    assert df.resolve_method(fsum, m_cls, "_upd").qname == "repro.core.m.raw"


def test_donate_through_fixpoint():
    df = dataflow({"src/repro/core/m.py": (
        "import functools\n"
        "import jax\n"
        "@functools.partial(jax.jit, donate_argnums=(0,))\n"
        "def upd(p, g):\n"
        "    return p\n"
        "def middle(buf, g):\n"
        "    return upd(buf, g)\n"
        "def outer(b, g):\n"
        "    return middle(b, g)\n")})
    assert df.index["repro.core.m.middle"].donated() == (0,)
    assert df.index["repro.core.m.outer"].donated() == (0,)


# ---------------------------------------------------------------------------
# local value flows
# ---------------------------------------------------------------------------

def _flows_of(src):
    df = dataflow({"src/repro/core/m.py": src})
    fi = df.file_summaries()[0].functions[0]
    return df.flows(fi), fi


def _origins(flows, fi):
    ret = [n for n in ast.walk(fi.node) if isinstance(n, ast.Return)][-1]
    return flows.origins(ret.value)


def test_localflows_param_and_attr_origins():
    flows, fi = _flows_of("def f(steps, inbox):\n"
                          "    x = steps\n"
                          "    y = inbox.coefs\n"
                          "    return (x, y)\n")
    labels = {(o.kind, o.label) for o in _origins(flows, fi)}
    assert ("param", "steps") in labels
    assert ("attr", "coefs") in labels


def test_localflows_substitution_tagging():
    flows, fi = _flows_of("import numpy as np\n"
                          "def f(t, PAD):\n"
                          "    stp = np.where(t > 0, np.int32(t), PAD)\n"
                          "    return stp\n")
    origins = _origins(flows, fi)
    by_label = {o.label: o for o in origins}
    assert by_label["t"].subst is True
    assert by_label["PAD"].subst is True


def test_localflows_wrapper_calls_keep_origins_untagged():
    flows, fi = _flows_of("import numpy as np\n"
                          "def f(steps):\n"
                          "    x = np.asarray(steps).astype(np.int32)\n"
                          "    return x\n")
    origins = _origins(flows, fi)
    assert {(o.label, o.subst) for o in origins} == {("steps", False)}


def test_localflows_subscript_store_merges_origins():
    flows, fi = _flows_of("import numpy as np\n"
                          "def f(sts, K, PAD):\n"
                          "    buf = np.full(K, PAD)\n"
                          "    buf[:2] = sts\n"
                          "    return buf\n")
    labels = {o.label for o in _origins(flows, fi)}
    assert "sts" in labels          # live slots carry the payload steps
    assert "PAD" in labels          # fill value (tagged subst)


# ---------------------------------------------------------------------------
# output renderers
# ---------------------------------------------------------------------------

_DIAG = Diagnostic("SF007", "src/repro/serve/server.py", 12, 5,
                   "jit inside a loop: 100% recompiles")


def test_github_renderer_escapes_and_locates():
    [line] = render_github([_DIAG])
    assert line.startswith("::error file=src/repro/serve/server.py,"
                           "line=12,col=5,title=sfcheck SF007::")
    assert "100%25 recompiles" in line      # % must be %25-escaped


def test_sarif_report_shape():
    report = sarif_report([_DIAG])
    assert report["version"] == "2.1.0"
    run = report["runs"][0]
    rule_ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert "SF007" in rule_ids and "SF000" in rule_ids
    [result] = run["results"]
    assert result["ruleId"] == "SF007"
    loc = result["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"] == "src/repro/serve/server.py"
    assert loc["region"] == {"startLine": 12, "startColumn": 5}
    json.dumps(report)                      # must be valid JSON end-to-end


def test_cli_format_flags(tmp_path, capsys):
    from repro.analysis.engine import main
    bad = tmp_path / "src" / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nnp.random.seed(0)\n")
    out = tmp_path / "report.sarif"
    rc = main([str(bad), "--format", "sarif", "--output", str(out)])
    assert rc == 1
    report = json.loads(out.read_text())
    assert [r["ruleId"] for r in report["runs"][0]["results"]] == ["SF001"]
    rc = main([str(bad), "--format", "github"])
    assert rc == 1
    captured = capsys.readouterr()
    assert "::error file=" in captured.out
