"""FROZEN pre-refactor monolith runner — the golden reference for the
Method x Transport plugin API (tests/test_golden_parity.py).

This is the verbatim training-loop code of the monolithic
``repro.dtrain.runner`` as of the commit that introduced the plugin API
(PR "Decompose the monolithic runner"), minus the config dataclasses (those
are imported from the live runner so configs stay interchangeable).  The
parity suite runs each method through BOTH implementations and asserts
bitwise-identical loss curves, byte ledgers and final parameters -- if you
change method math in the plugins, you must consciously retire or update
this file.

Not a test module; imported by test_golden_parity.py only.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ChurnConfig
from repro.core import flood, gossip, messages, seeds as seedlib, subcge, zo
from repro.core.messages import Message, MESSAGE_BYTES
from repro.data import synthetic
from repro.dtrain import lora as loralib
from repro.dtrain.runner import DTrainConfig, RunResult, sim_arch
from repro.models import params as plib
from repro.models import transformer as tf
from repro.models.perturb import (Pert, epoch_subspace, nest_subspace,
                                  sample_pert)
from repro.topology import graphs
from repro.topology.dynamic import ChurnSchedule, DynamicTopology
from repro.core.subcge import SubCGEConfig


# ---------------------------------------------------------------------------
# shared scaffolding
# ---------------------------------------------------------------------------

class _Setup:
    def __init__(self, cfg: DTrainConfig):
        self.cfg = cfg
        self.arch = cfg.arch or sim_arch()
        self.task = cfg.task or synthetic.TaskConfig(vocab=self.arch.vocab)
        self.train, self.valid, self.test = synthetic.make_splits(self.task)
        self.parts = synthetic.partition(self.train, cfg.n_clients,
                                         scheme=cfg.partition, seed=cfg.seed)
        self.graph = graphs.make(cfg.topology, cfg.n_clients)
        self.W = graphs.metropolis_weights(self.graph)
        self.spec = tf.arch_spec(self.arch)
        p0 = plib.init_params(self.spec, cfg.seed)
        self.stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (cfg.n_clients,) + l.shape), p0)
        self.meta = plib.subcge_meta(self.spec)
        self.scfg = SubCGEConfig(rank=cfg.subcge_rank,
                                 refresh_period=cfg.subcge_tau, eps=cfg.eps)
        self.n_params = plib.n_params(self.spec)

    def batches(self, step: int):
        return synthetic.stacked_batches(self.train, self.parts, step,
                                         self.cfg.batch_size, self.cfg.seed)

    def gmp(self, stacked) -> float:
        avg = jax.tree.map(lambda l: l.mean(axis=0), stacked)
        return synthetic.accuracy(self.arch, avg, self.test,
                                  forward_fn=tf.forward)

    def valid_loss(self, stacked) -> float:
        avg = jax.tree.map(lambda l: l.mean(axis=0), stacked)
        toks = jnp.asarray(self.valid.tokens[:128])
        return float(tf.lm_loss(self.arch, avg, {"tokens": toks}))


def _churn_schedule(cfg: DTrainConfig) -> ChurnSchedule | None:
    if cfg.churn is None:
        return None
    if isinstance(cfg.churn, ChurnSchedule):
        return cfg.churn
    if isinstance(cfg.churn, ChurnConfig):
        return ChurnSchedule.from_config(cfg.churn)
    raise TypeError(f"churn must be a ChurnSchedule or ChurnConfig, "
                    f"got {type(cfg.churn).__name__}")


def _require_static(cfg: DTrainConfig, method: str) -> None:
    if cfg.churn is not None:
        raise ValueError(f"method '{method}' does not support churn")


def _active_consensus(stacked, active: np.ndarray) -> float:
    """Consensus error over online clients only (offline params are frozen
    snapshots — counting them would conflate churn with divergence)."""
    idx = np.flatnonzero(active)
    if idx.size <= 1:
        return 0.0
    sub = jax.tree.map(lambda l: l[idx], stacked)
    return float(gossip.consensus_error(sub))


def _freeze_offline(new, old, active: np.ndarray):
    """Keep offline clients' leaves at their pre-step values."""
    mask = jnp.asarray(active)

    def f(a, b):
        m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)

    return jax.tree.map(f, new, old)


def _log_loss(loss_curve: list[float], losses: np.ndarray,
              active: np.ndarray) -> None:
    """Mean loss over online clients; under a full outage nobody computed a
    step, so carry the previous loss instead of averaging an empty slice
    (NaN + RuntimeWarning)."""
    if active.any():
        loss_curve.append(float(np.mean(losses[active])))
    else:
        loss_curve.append(loss_curve[-1] if loss_curve else float("nan"))


# ---------------------------------------------------------------------------
# SeedFlood (Algorithm 1)
# ---------------------------------------------------------------------------

def run_seedflood(cfg: DTrainConfig) -> RunResult:
    s = _Setup(cfg)
    n = cfg.n_clients
    churn = _churn_schedule(cfg)
    net = flood.make_network(s.graph, backend=cfg.flood_backend)
    meta, scfg, arch = s.meta, s.scfg, s.arch

    # ---- jitted pieces ----------------------------------------------------
    def local_estimate(params_i, batch_i, seed_i, sub):
        pert = sample_pert(meta, scfg, seed_i, scfg.eps)
        lp = tf.lm_loss(arch, params_i, batch_i, sub=sub, pert=pert)
        lm = tf.lm_loss(arch, params_i, batch_i, sub=sub,
                        pert=pert.with_scale(-scfg.eps))
        return (lp - lm) / (2 * scfg.eps), 0.5 * (lp + lm)

    # (A)+(B) fused, batched path: one dispatch over the stacked client axis
    # computes every ZO estimate, the -η·α/n_eff coefficients, and each
    # online client's own local update (offline clients get coef 0, an exact
    # no-op).  Buffers are donated — params update in place.
    @functools.partial(jax.jit, donate_argnums=(0,))
    def estimate_and_update(stacked, tokens, seeds_t, step, active_f):
        sub = subcge.subspace_at_step(meta, scfg, cfg.seed, step)
        sub_n = nest_subspace(sub)
        alphas, losses = jax.vmap(
            lambda p, b, sd: local_estimate(p, {"tokens": b}, sd, sub_n)
        )(stacked, tokens, seeds_t)
        n_eff = jnp.maximum(jnp.sum(active_f), 1.0)
        coefs = -cfg.lr * alphas / n_eff
        own = jnp.where(active_f > 0, coefs, 0.0)
        new = jax.vmap(lambda p, sd, c: subcge.apply_messages(
            p, meta, scfg, sub, sd[None], c[None]))(stacked, seeds_t, own)
        return new, losses, coefs

    # estimate only — the per-client reference path updates in a host loop
    @jax.jit
    def estimate_all(stacked, tokens, seeds_t, step):
        sub_n = epoch_subspace(meta, scfg, cfg.seed, step)
        return jax.vmap(
            lambda p, b, sd: local_estimate(p, {"tokens": b}, sd, sub_n)
        )(stacked, tokens, seeds_t)

    @jax.jit
    def update_one(p, sds, cfs, step):
        sub = subcge.subspace_at_step(meta, scfg, cfg.seed, step)
        return subcge.apply_messages(p, meta, scfg, sub, sds, cfs)

    # (C) replay: every received message under ITS SENDER's subspace epoch —
    # the reconstruction guarantee survives τ-refresh boundaries (delayed
    # flooding, anti-entropy catch-up).  Batched variant is one dispatch
    # over the (n, K) padded payload matrices; jax's shape cache bounds
    # retraces because K and E are pow2-bucketed.
    @functools.partial(jax.jit, donate_argnums=(0,))
    def replay_batched(stacked, sds, cfs, stp, epochs):
        return jax.vmap(
            lambda p, sd, cf, st: subcge.apply_messages_epoch(
                p, meta, scfg, cfg.seed, sd, cf, st, epochs)
        )(stacked, sds, cfs, stp)

    @jax.jit
    def replay_one(p, sds, cfs, stp, epochs):
        return subcge.apply_messages_epoch(p, meta, scfg, cfg.seed,
                                           sds, cfs, stp, epochs)

    def replay_payloads(stacked, sds, cfs, stp, t):
        """Apply one (n, K) padded payload batch to all clients."""
        if sds.shape[1] == 0:
            return stacked
        if not cfg.epoch_replay:
            # legacy receiver-step replay (regression demonstration only):
            # pin every live message to the receiver's current epoch
            stp = np.where(cfs != 0.0, np.int32(t), np.int32(flood.STEP_PAD))
        epochs = jnp.asarray(subcge.epoch_slots(stp, scfg))
        if cfg.batched_step:
            return replay_batched(stacked, jnp.asarray(sds), jnp.asarray(cfs),
                                  jnp.asarray(stp), epochs)
        new_stacked = []
        for i in range(n):
            p_i = jax.tree.map(lambda l: l[i], stacked)
            if (cfs[i] != 0.0).any():
                p_i = replay_one(p_i, jnp.asarray(sds[i]), jnp.asarray(cfs[i]),
                                 jnp.asarray(stp[i]), epochs)
            new_stacked.append(p_i)
        return jax.tree.map(lambda *ls: jnp.stack(ls), *new_stacked)

    # ---- training loop ------------------------------------------------------
    stacked = s.stacked
    active = net.active_mask()
    loss_curve, acc_curve, consensus_curve = [], [], []
    step_wall_s = []     # per-step seconds ([0] includes compile; bench_step)
    t0 = time.time()
    for t in range(cfg.steps):
        t_step = time.perf_counter()
        # churn events land at the start of the step; rejoined clients carry
        # their anti-entropy catch-up messages into this step's apply phase
        pending = None
        if churn is not None and churn.events_at(t):
            net.apply_churn(churn.events_at(t))
            active = net.active_mask()
            pending = net.drain_catchup_arrays()
        # full flooding tracks the *effective* diameter, which churn moves
        k_hops = cfg.flood_k if cfg.flood_k is not None else net.diameter

        batch = s.batches(t)
        seeds_np = seedlib.client_seeds(cfg.seed, t, n)   # hoisted: no retrace
        seeds_t = jnp.asarray(seeds_np)

        if cfg.batched_step:
            stacked, losses, coefs_j = estimate_and_update(
                stacked, batch["tokens"], seeds_t, t,
                jnp.asarray(active, jnp.float32))
            coefs = np.asarray(coefs_j)
        else:
            alphas, losses = estimate_all(stacked, batch["tokens"], seeds_t, t)
            n_eff = max(int(active.sum()), 1)   # == n on a static topology
            # float32 like the fused path (numpy would silently promote)
            coefs = (-cfg.lr * np.asarray(alphas) / n_eff).astype(np.float32)
            # (B) local update: each online client applies its own message
            # immediately; offline clients freeze (no step, no message)
            new_stacked = []
            for i in range(n):
                p_i = jax.tree.map(lambda l: l[i], stacked)
                if active[i]:
                    p_i = update_one(p_i, seeds_t[i:i + 1],
                                     jnp.asarray(coefs[i:i + 1]), t)
                new_stacked.append(p_i)
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new_stacked)

        _log_loss(loss_curve, np.asarray(losses), active)

        # (C) online clients inject their fresh messages into the flood
        for i in range(n):
            if active[i]:
                net.inject(i, Message(seed=int(seeds_np[i]),
                                      coef=float(coefs[i]), origin=i, step=t))

        # flooding: k hops per local iteration (frontiers persist — delayed
        # flooding semantics when k < diameter); anti-entropy catch-up rides
        # in front of fresh floods in the same padded matrices
        sds, cfs, stp = net.rounds_padded(k_hops, extra=pending)
        stacked = replay_payloads(stacked, sds, cfs, stp, t)
        jax.block_until_ready(stacked)
        step_wall_s.append(time.perf_counter() - t_step)

        if cfg.eval_every and (t + 1) % cfg.eval_every == 0:
            acc_curve.append((t + 1, s.gmp(stacked)))
            consensus_curve.append((t + 1, _active_consensus(stacked, active)))

    if cfg.drain:
        # flush in-flight delayed-flooding messages: flood + replay with no
        # new injections until quiescent, so every sent message is applied
        for _ in range(cfg.steps + 1):
            if net.in_flight() == 0:
                break
            sds, cfs, stp = net.rounds_padded(net.diameter + 1)
            stacked = replay_payloads(stacked, sds, cfs, stp, cfg.steps)

    gmp = s.gmp(stacked)
    k_label = cfg.flood_k if cfg.flood_k is not None else net.diameter
    return RunResult(
        method=f"seedflood(k={k_label})", gmp=gmp, loss_curve=loss_curve,
        acc_curve=acc_curve, bytes_per_edge=net.ledger.per_edge,
        total_bytes=net.ledger.total_bytes,
        consensus_error=_active_consensus(stacked, active),
        wall_s=time.time() - t0,
        extra={"n_messages": net.ledger.n_messages, "diameter": net.diameter,
               "n_params": s.n_params, "consensus_curve": consensus_curve,
               "sync_bytes": net.ledger.sync_bytes,
               "n_syncs": net.ledger.n_syncs,
               "step_wall_s": step_wall_s,
               "final_stacked": stacked})


# ---------------------------------------------------------------------------
# gossip baselines
# ---------------------------------------------------------------------------

def _gossip_common(cfg: DTrainConfig, *, zeroth_order: bool, use_lora: bool,
                   choco: bool) -> RunResult:
    s = _Setup(cfg)
    n = cfg.n_clients
    arch, meta = s.arch, s.meta
    ledger = messages.CommLedger(n_edges=s.graph.number_of_edges())
    n_edges = s.graph.number_of_edges()

    # churn: gossip has no anti-entropy — offline clients freeze and the
    # mixing matrix shrinks to the live subgraph (frozen rows become e_i)
    churn = _churn_schedule(cfg)
    topo = DynamicTopology(s.graph) if churn is not None else None
    active = np.ones(n, dtype=bool)
    W = s.W
    live_edges = n_edges

    lspec = None
    lora_stacked = None
    if use_lora:
        lspec = loralib.lora_spec(s.spec, r=cfg.lora_r)
        l0 = loralib.lora_init(lspec, cfg.seed + 1)
        lora_stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape), l0)
        payload = loralib.n_lora_params(lspec) * 4
    else:
        payload = s.n_params * 4

    def full_params(base_i, lora_i):
        if use_lora:
            return loralib.merge(base_i, lora_i, cfg.lora_alpha)
        return base_i

    # ---- local step ---------------------------------------------------------
    if zeroth_order:
        @jax.jit
        def local_steps(base, trainable, batch, seeds_t):
            def one(b_i, tr_i, toks, sd):
                if use_lora:
                    loss_fn = lambda l: tf.lm_loss(arch, full_params(b_i, l),
                                                   {"tokens": toks})
                else:
                    loss_fn = lambda p: tf.lm_loss(arch, p, {"tokens": toks})
                z = zo.mezo_z(tr_i, sd)
                lp = loss_fn(zo.tree_add_scaled(tr_i, z, cfg.eps))
                lm = loss_fn(zo.tree_add_scaled(tr_i, z, -cfg.eps))
                a = (lp - lm) / (2 * cfg.eps)
                return zo.tree_add_scaled(tr_i, z, -cfg.lr * a), 0.5 * (lp + lm)
            return jax.vmap(one)(base, trainable, batch["tokens"], seeds_t)
    else:
        @jax.jit
        def local_steps(base, trainable, batch):
            def one(b_i, tr_i, toks):
                if use_lora:
                    loss_fn = lambda l: tf.lm_loss(arch, full_params(b_i, l),
                                                   {"tokens": toks})
                else:
                    loss_fn = lambda p: tf.lm_loss(arch, p, {"tokens": toks})
                loss, g = jax.value_and_grad(loss_fn)(tr_i)
                new = jax.tree.map(lambda p, gg: p - cfg.lr * gg.astype(p.dtype),
                                   tr_i, g)
                return new, loss
            return jax.vmap(one, in_axes=(0, 0, 0))(base, trainable, batch["tokens"])

    trainable = lora_stacked if use_lora else s.stacked
    base = s.stacked
    choco_state = gossip.choco_init(trainable) if choco else None

    loss_curve, acc_curve, consensus_curve = [], [], []
    t0 = time.time()
    for t in range(cfg.steps):
        if topo is not None and churn.events_at(t):
            topo.apply_events(churn.events_at(t))
            active = topo.active_mask()
            W = graphs.metropolis_weights(topo.current_graph())
            live_edges = topo.live_edge_count()

        batch = s.batches(t)
        if zeroth_order:
            seeds_t = jnp.asarray(seedlib.client_seeds(cfg.seed, t, n))
            new_trainable, stat = local_steps(base, trainable, batch, seeds_t)
        else:
            new_trainable, stat = local_steps(base, trainable, batch)
        trainable = (_freeze_offline(new_trainable, trainable, active)
                     if topo is not None else new_trainable)
        _log_loss(loss_curve, np.asarray(stat), active)

        if (t + 1) % cfg.local_iters == 0:
            if choco:
                trainable, choco_state = gossip.choco_round(
                    trainable, choco_state, W, cfg.choco_density,
                    active=active if topo is not None else None)
                ledger.send(2 * live_edges * messages.topk_payload_bytes(
                    payload // 4, cfg.choco_density))
            else:
                trainable = gossip.mix(trainable, W)
                ledger.send(2 * live_edges * payload)
        if cfg.eval_every and (t + 1) % cfg.eval_every == 0:
            merged = jax.vmap(full_params)(base, trainable) if use_lora else trainable
            acc_curve.append((t + 1, s.gmp(merged)))
            consensus_curve.append((t + 1, _active_consensus(merged, active)))

    merged = jax.vmap(full_params)(base, trainable) if use_lora else trainable
    name = ("choco" if choco else ("dzsgd" if zeroth_order else "dsgd"))
    if use_lora:
        name += "_lora"
    return RunResult(
        method=name, gmp=s.gmp(merged), loss_curve=loss_curve,
        acc_curve=acc_curve, bytes_per_edge=ledger.per_edge,
        total_bytes=ledger.total_bytes,
        consensus_error=_active_consensus(merged, active),
        wall_s=time.time() - t0,
        extra={"n_params": s.n_params, "consensus_curve": consensus_curve})


def run_dsgd(cfg):   return _gossip_common(cfg, zeroth_order=False, use_lora=False, choco=False)
def run_dzsgd(cfg):  return _gossip_common(cfg, zeroth_order=True, use_lora=False, choco=False)
def run_choco(cfg):  return _gossip_common(cfg, zeroth_order=False, use_lora=False, choco=True)
def run_dsgd_lora(cfg):  return _gossip_common(cfg, zeroth_order=False, use_lora=True, choco=False)
def run_dzsgd_lora(cfg): return _gossip_common(cfg, zeroth_order=True, use_lora=True, choco=False)
def run_choco_lora(cfg): return _gossip_common(cfg, zeroth_order=False, use_lora=True, choco=True)


# ---------------------------------------------------------------------------
# gossip with shared randomness (§3.2 strawman — O(tn) comm, O(tnd) compute)
# ---------------------------------------------------------------------------

def run_gossip_sr(cfg: DTrainConfig) -> RunResult:
    _require_static(cfg, "gossip_sr")
    s = _Setup(cfg)
    n = cfg.n_clients
    arch, meta, scfg = s.arch, s.meta, s.scfg
    ledger = messages.CommLedger(n_edges=s.graph.number_of_edges())
    neigh = graphs.neighbors(s.graph)
    W = s.W

    # per-client coefficient ledgers: uid -> [seed, alpha_scaled, coef_i]
    hist: list[dict] = [dict() for _ in range(n)]
    stacked = s.stacked
    applied: list[dict] = [dict() for _ in range(n)]  # uid -> coef already in θ_i

    @jax.jit
    def estimate_all(stacked_p, batch, seeds_t, step):
        sub = epoch_subspace(meta, scfg, cfg.seed, step)
        def one(p, toks, sd):
            pert = sample_pert(meta, scfg, sd, scfg.eps)
            lp = tf.lm_loss(arch, p, {"tokens": toks}, sub=sub, pert=pert)
            lm = tf.lm_loss(arch, p, {"tokens": toks}, sub=sub,
                            pert=pert.with_scale(-scfg.eps))
            return (lp - lm) / (2 * scfg.eps), 0.5 * (lp + lm)
        return jax.vmap(one)(stacked_p, batch["tokens"], seeds_t)

    @jax.jit
    def apply_deltas_fn(p, ss, cc, stp, epochs):
        return subcge.apply_messages_epoch(p, meta, scfg, cfg.seed,
                                           ss, cc, stp, epochs)

    def apply_deltas(p_i, sds, cfs, sts):
        """Epoch-correct delta replay: a reweighted coefficient for message
        (i, t0) must re-apply under the subspace of ITS origin step t0 —
        history reweighting routinely reaches across τ boundaries."""
        K = flood.pad_pow2(len(sds))
        pad_s = np.zeros(K, np.uint32); pad_s[:len(sds)] = sds
        pad_c = np.zeros(K, np.float32); pad_c[:len(cfs)] = cfs
        pad_t = np.full(K, flood.STEP_PAD, np.int32); pad_t[:len(sts)] = sts
        epochs = jnp.asarray(subcge.epoch_slots(pad_t, scfg))
        return apply_deltas_fn(p_i, jnp.asarray(pad_s), jnp.asarray(pad_c),
                               jnp.asarray(pad_t), epochs)

    loss_curve = []
    reconstructions = 0
    t0 = time.time()
    for t in range(cfg.steps):
        batch = s.batches(t)
        seeds_np = seedlib.client_seeds(cfg.seed, t, n)
        seeds_t = jnp.asarray(seeds_np)
        alphas, losses = estimate_all(stacked, batch, seeds_t, t)
        alphas = np.asarray(alphas)
        loss_curve.append(float(np.mean(np.asarray(losses))))
        for i in range(n):
            uid = (i, t)
            hist[i][uid] = [int(seeds_np[i]), float(-cfg.lr * alphas[i]), 1.0]

        if (t + 1) % cfg.local_iters == 0:
            # exchange full histories; average coefficients (eq. 8)
            all_uids = set()
            for i in range(n):
                all_uids |= set(hist[i].keys())
            for i in range(n):
                for j in neigh[i]:
                    ledger.send(len(hist[j]) * MESSAGE_BYTES, count=len(hist[j]))
            new_hist = []
            for i in range(n):
                h = {}
                for uid in all_uids:  # sfcheck: noqa[SF003] -- FROZEN pre-refactor oracle; int-tuple uid order is deterministic and must stay byte-identical to the live transport
                    cbar = sum(W[i, j] * hist[j].get(uid, [0, 0, 0.0])[2]
                               for j in range(n) if W[i, j] > 0)
                    ref = next(hist[j][uid] for j in range(n) if uid in hist[j])
                    h[uid] = [ref[0], ref[1], cbar]
                new_hist.append(h)
            hist = new_hist

        # incremental re-application of coefficient deltas: O(t·n·d) — the
        # §3.2 cost blow-up, measured
        new_stacked = []
        for i in range(n):
            p_i = jax.tree.map(lambda l: l[i], stacked)
            sds, cfs, sts = [], [], []
            for uid, (sd, a_scaled, c) in hist[i].items():
                prev = applied[i].get(uid, 0.0)
                delta = c * a_scaled - prev
                if abs(delta) > 0:
                    sds.append(sd); cfs.append(delta); sts.append(uid[1])
                    applied[i][uid] = c * a_scaled
            if sds:
                reconstructions += len(sds)
                p_i = apply_deltas(p_i, np.asarray(sds, np.uint32),
                                   np.asarray(cfs, np.float32),
                                   np.asarray(sts, np.int32))
            new_stacked.append(p_i)
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new_stacked)

    return RunResult(
        method="gossip_sr", gmp=s.gmp(stacked), loss_curve=loss_curve,
        acc_curve=[], bytes_per_edge=ledger.per_edge,
        total_bytes=ledger.total_bytes,
        consensus_error=float(gossip.consensus_error(stacked)),
        wall_s=time.time() - t0,
        extra={"reconstructions": reconstructions, "n_params": s.n_params})


# ---------------------------------------------------------------------------
# centralized ZO oracle (equivalence target for tests)
# ---------------------------------------------------------------------------

def run_central_zo(cfg: DTrainConfig) -> RunResult:
    """Centralized SubCGE-ZO with n perturbations per step, averaging the n
    two-point estimates — mathematically identical to SeedFlood under full
    flooding (same seeds, same batches)."""
    _require_static(cfg, "central_zo")
    s = _Setup(cfg)
    n = cfg.n_clients
    arch, meta, scfg = s.arch, s.meta, s.scfg

    @jax.jit
    def step_fn(params, velocity, batch, seeds_t, step):
        sub = subcge.subspace_at_step(meta, scfg, cfg.seed, step)
        sub_n = nest_subspace(sub)
        def one(toks, sd):
            pert = sample_pert(meta, scfg, sd, scfg.eps)
            lp = tf.lm_loss(arch, params, {"tokens": toks}, sub=sub_n, pert=pert)
            lm = tf.lm_loss(arch, params, {"tokens": toks}, sub=sub_n,
                            pert=pert.with_scale(-scfg.eps))
            return (lp - lm) / (2 * scfg.eps), 0.5 * (lp + lm)
        alphas, losses = jax.vmap(one)(batch["tokens"], seeds_t)
        coefs = -cfg.lr * alphas / n
        if cfg.momentum > 0.0:
            # beyond-paper: momentum in the r×r coefficient space (O(r²)
            # state/leaf, consensus-safe; velocity resets at τ-refresh
            # since it is only meaningful within its subspace window)
            is_refresh = jnp.logical_and(step > 0,
                                         step % scfg.refresh_period == 0)
            velocity = {p: jnp.where(is_refresh, jnp.zeros_like(v), v)
                        for p, v in velocity.items()}
            new, velocity = subcge.momentum_apply(
                params, meta, scfg, sub, velocity, seeds_t, coefs,
                beta=cfg.momentum)
        else:
            new = subcge.apply_messages(params, meta, scfg, sub, seeds_t, coefs)
        return new, velocity, jnp.mean(losses)

    params = jax.tree.map(lambda l: l[0], s.stacked)
    velocity = subcge.zero_buffers(meta, scfg)
    loss_curve = []
    t0 = time.time()
    for t in range(cfg.steps):
        batch = s.batches(t)
        seeds_t = jnp.asarray(seedlib.client_seeds(cfg.seed, t, n))
        params, velocity, loss = step_fn(params, velocity, batch, seeds_t, t)
        loss_curve.append(float(loss))

    stacked = jax.tree.map(lambda l: l[None], params)
    return RunResult(
        method="central_zo", gmp=s.gmp(stacked), loss_curve=loss_curve,
        acc_curve=[], bytes_per_edge=0.0, total_bytes=0.0,
        consensus_error=0.0, wall_s=time.time() - t0,
        extra={"n_params": s.n_params, "final_params": params})


METHODS: dict[str, Callable[[DTrainConfig], RunResult]] = {
    "seedflood": run_seedflood,
    "dsgd": run_dsgd,
    "dzsgd": run_dzsgd,
    "choco": run_choco,
    "dsgd_lora": run_dsgd_lora,
    "dzsgd_lora": run_dzsgd_lora,
    "choco_lora": run_choco_lora,
    "gossip_sr": run_gossip_sr,
    "central_zo": run_central_zo,
}


def run(cfg: DTrainConfig) -> RunResult:
    if cfg.method not in METHODS:
        raise KeyError(f"unknown method '{cfg.method}' (have {sorted(METHODS)})")
    return METHODS[cfg.method](cfg)
