"""Event-driven simulator (repro.sim): queue determinism, trace math, the
sync≡async bitwise oracles, heterogeneous determinism, config validation."""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.dtrain.runner import DTrainConfig, run, sim_arch, validate_config
from repro.sim import (EventQueue, Episode, TraceSet, as_trace,
                       barrier_schedule, time_to_loss)
from repro.sim import events
from repro.topology.dynamic import ChurnSchedule


def _cfg(**kw):
    base = dict(n_clients=4, topology="ring", steps=3, lr=1e-2, batch_size=4,
                subcge_rank=8, local_iters=2,
                arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    base.update(kw)
    return DTrainConfig(**base)


def _stacked_equal(a, b) -> bool:
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _assert_oracle(r_sync, r_async, check_final=True):
    """The bitwise sync≡async contract: curves, ledger, final parameters."""
    assert r_sync.loss_curve == r_async.loss_curve
    assert r_sync.acc_curve == r_async.acc_curve
    assert r_sync.total_bytes == r_async.total_bytes
    for key in ("n_messages", "sync_bytes", "n_syncs"):   # flood-only stats
        assert r_sync.extra.get(key) == r_async.extra.get(key)
    assert r_sync.gmp == r_async.gmp
    assert r_sync.consensus_error == r_async.consensus_error
    if check_final:
        assert _stacked_equal(r_sync.extra["final_stacked"],
                              r_async.extra["final_stacked"])


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_content_not_insertion():
    """Pop order is a pure function of event content: any permutation of the
    pushes yields the same sequence (the determinism the tiebreak rule
    promises)."""
    evs = [events.step_event(1.0, 2, 0), events.step_event(1.0, 0, 0),
           events.deliver_event(1.0, 1, 0, 1, ()),
           events.deliver_event(1.0, 1, 0, 2, ()),
           events.deliver_event(1.0, 3, 2, 1, ()),
           events.churn_event(1.0, 1), events.step_event(0.5, 3, 0)]
    orders = [evs, evs[::-1], evs[3:] + evs[:3]]
    popped = []
    for order in orders:
        q = EventQueue()
        for ev in order:
            q.push(ev)
        popped.append([q.pop() for _ in range(len(order))])
    assert popped[0] == popped[1] == popped[2]
    # and the ranking is STEP < DELIVER < CHURN at equal time
    ranks = [ev.rank for ev in popped[0] if ev.time == 1.0]
    assert ranks == sorted(ranks)


def test_event_queue_peek_and_len():
    q = EventQueue()
    assert q.peek() is None and not q
    q.push(events.step_event(2.0, 0, 1))
    q.push(events.step_event(1.0, 0, 0))
    assert len(q) == 2 and q.peek().time == 1.0
    assert q.pop().step == 0


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def test_trace_json_roundtrip(tmp_path):
    trace = TraceSet(
        compute_s=(1.0, 2.5), bandwidth_bps=(1e6, math.inf),
        latency_s=(0.01, 0.0),
        episodes=(Episode(0, 3.0, 5.0, "straggle", 2.0),
                  Episode(1, 1.0, 2.0, "preempt")))
    path = str(tmp_path / "trace.json")
    trace.save(path)
    assert TraceSet.load(path) == trace
    # infinite bandwidth survives as JSON null
    assert json.loads(open(path).read())["bandwidth_bps"][1] is None
    assert as_trace(path, 2) == trace
    assert as_trace(trace.to_json(), 2) == trace
    with pytest.raises(ValueError, match="covers 2 clients"):
        as_trace(trace, 3)


def test_trace_validation():
    with pytest.raises(ValueError, match="positive"):
        TraceSet((0.0,), (1.0,), (0.0,))
    with pytest.raises(ValueError, match="lengths"):
        TraceSet((1.0, 1.0), (1.0,), (0.0,))
    with pytest.raises(ValueError, match="overlapping"):
        TraceSet((1.0,), (math.inf,), (0.0,),
                 episodes=(Episode(0, 0.0, 2.0, "preempt"),
                           Episode(0, 1.0, 3.0, "preempt")))
    with pytest.raises(ValueError, match="kind"):
        Episode(0, 0.0, 1.0, "pause")


def test_finish_time_integrates_episodes():
    trace = TraceSet((1.0,), (math.inf,), (0.0,),
                     episodes=(Episode(0, 1.0, 2.0, "preempt"),
                               Episode(0, 4.0, 6.0, "straggle", 2.0)))
    # 1.0s of work starting at 0.5: runs 0.5s, stalls [1,2), finishes at 2.5
    assert trace.finish_time(0, 0.5, 1.0) == 2.5
    # 1.0s of work starting at 4.0 at half rate finishes at 6.0... exactly
    # consumes the episode; 0.5s of work takes 1.0s wall
    assert trace.finish_time(0, 4.0, 0.5) == 5.0
    # no episodes in the way: plain addition
    assert trace.finish_time(0, 10.0, 1.0) == 11.0


def test_edge_delay_formula():
    trace = TraceSet((1.0, 1.0), (8e3, 4e3), (0.010, 0.020))
    # min bandwidth wins: 100 bytes * 8 / 4e3 bps = 0.2s serialization
    assert trace.edge_delay(0, 1, 100) == pytest.approx(0.010 + 0.020 + 0.2)
    assert trace.edge_delay(0, 1, 100, extra_latency=0.1) == pytest.approx(
        0.33)
    inf = TraceSet.constant(2)
    assert inf.edge_delay(0, 1, 10**9) == 0.0


def test_barrier_schedule_waits_for_slowest():
    trace = TraceSet.two_speed(4, fast_s=1.0, slow_s=4.0)
    assert barrier_schedule(trace, 3) == [4.0, 8.0, 12.0]
    assert time_to_loss([(1.0, 5.0), (2.0, 4.0), (3.0, 4.5)], 4.0) == 2.0
    assert time_to_loss([(1.0, 5.0)], 1.0) == math.inf


# ---------------------------------------------------------------------------
# the bitwise oracles: homogeneous zero-latency event run == synchronous run
# ---------------------------------------------------------------------------

def test_async_seedflood_matches_sync_bitwise():
    """The tentpole guarantee: with TraceSet.constant the event loop
    reproduces the synchronous seedflood run bitwise — loss/acc curves, the
    byte ledger, and final stacked parameters.  (The sync side drains so
    both engines charge the trailing re-flood hops.)"""
    cfg = _cfg(method="seedflood", n_clients=6, steps=8, subcge_tau=3,
               eval_every=4, drain=True)
    r_sync = run(cfg)
    r_async = run(dataclasses.replace(cfg, drain=False,
                                      trace=TraceSet.constant(6)))
    _assert_oracle(r_sync, r_async)
    assert r_async.extra["virtual_time_s"] == 8.0
    assert len(r_async.extra["loss_vs_virtual_time"]) == 8


def test_async_seedflood_churn_matches_sync_bitwise():
    """Same contract under leave/rejoin churn: the event loop maps churn
    step T to virtual time T·ref, anti-entropy catch-up is deferred to the
    post-cohort merge, and the departing node's unreleased frontier stays
    uncharged — ledger equality is exact, not just final-state equality."""
    cfg = _cfg(method="seedflood", n_clients=6, steps=8, subcge_tau=3,
               eval_every=0, drain=True,
               churn=ChurnSchedule.leave_rejoin([2], 2, 4))
    r_sync = run(cfg)
    r_async = run(dataclasses.replace(cfg, drain=False,
                                      trace=TraceSet.constant(6)))
    _assert_oracle(r_sync, r_async)


def test_async_gossip_matches_sync_bitwise():
    """The gossip adapter keeps mixing a barrier; with a homogeneous trace
    the event run is the synchronous dzsgd run bitwise (gossip has no
    final_stacked — curves, gmp, consensus, and bytes are the contract)."""
    cfg = _cfg(method="dzsgd", steps=6, local_iters=2, eval_every=2)
    r_sync = run(cfg)
    r_async = run(dataclasses.replace(cfg, trace=TraceSet.constant(4)))
    _assert_oracle(r_sync, r_async, check_final=False)


# ---------------------------------------------------------------------------
# heterogeneous runs: deterministic, insertion-order independent
# ---------------------------------------------------------------------------

def test_heterogeneous_run_is_deterministic():
    trace = TraceSet.lognormal(6, sigma=0.8, seed=3)
    cfg = _cfg(method="seedflood", n_clients=6, steps=5, trace=trace)
    r1, r2 = run(cfg), run(cfg)
    assert r1.loss_curve == r2.loss_curve
    assert r1.extra["loss_vs_virtual_time"] == r2.extra["loss_vs_virtual_time"]
    assert r1.total_bytes == r2.total_bytes
    assert _stacked_equal(r1.extra["final_stacked"],
                          r2.extra["final_stacked"])
    # per-client cohorts: more loss entries than steps
    assert len(r1.loss_curve) > cfg.steps


def test_event_order_independent_of_insertion_order():
    """Scheduling the initial STEP events in reversed client order must not
    change anything — the queue orders on content, and same-key cascades
    are themselves key-ordered."""
    from repro.dtrain.api import Setup
    from repro.dtrain.methods import METHOD_SPECS
    from repro.sim import EventTrainer, wrap_async

    trace = TraceSet.lognormal(4, sigma=0.6, seed=1)
    cfg = _cfg(method="seedflood", steps=4, trace=trace,
               flood_backend="python")
    spec = METHOD_SPECS["seedflood"]

    def run_order(order):
        setup = Setup(cfg)
        transport = wrap_async(spec.make_transport(cfg, setup), trace)
        return EventTrainer(cfg, setup, spec.make_method(cfg), transport,
                            trace, init_order=order).run()

    r_fwd = run_order([0, 1, 2, 3])
    r_rev = run_order([3, 2, 1, 0])
    assert r_fwd.loss_curve == r_rev.loss_curve
    assert r_fwd.total_bytes == r_rev.total_bytes
    assert _stacked_equal(r_fwd.extra["final_stacked"],
                          r_rev.extra["final_stacked"])


def test_straggler_episode_slows_only_its_client():
    base = TraceSet.constant(4)
    ep = TraceSet((1.0,) * 4, (math.inf,) * 4, (0.0,) * 4,
                  episodes=(Episode(2, 0.0, 100.0, "straggle", 3.0),))
    cfg = _cfg(method="seedflood", steps=4)
    r0 = run(dataclasses.replace(cfg, trace=base))
    r1 = run(dataclasses.replace(cfg, trace=ep))
    assert r0.extra["virtual_time_s"] == 4.0
    assert r1.extra["virtual_time_s"] == 12.0  # client 2 at 1/3 rate
    # everyone still takes all 4 steps: 3 fast cohorts + 1 straggler each
    assert len(r1.loss_curve) == 8


def test_async_beats_barrier_on_time_to_loss():
    """Under 4× compute heterogeneity the async swarm reaches the barrier
    run's final loss in strictly less virtual time (the headline metric of
    BENCH_async.json, pinned at miniature scale)."""
    trace = TraceSet.two_speed(6, fast_s=1.0, slow_s=4.0)
    cfg = _cfg(method="seedflood", n_clients=6, steps=6)
    r_sync = run(cfg)
    r_async = run(dataclasses.replace(cfg, trace=trace))
    barrier = barrier_schedule(trace, cfg.steps)
    sync_curve = list(zip(barrier, r_sync.loss_curve))
    target = max(min(r_sync.loss_curve), min(r_async.loss_curve))
    assert time_to_loss(r_async.extra["loss_vs_virtual_time"], target) \
        < time_to_loss(sync_curve, target)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(trace="t.json", method="central_zo"), "trace"),
    (dict(trace="t.json", method="gossip_sr"), "trace"),
    (dict(sim_latency_s=0.5), "set 'trace' as well"),
    (dict(sim_churn_step_s=1.0), "set 'trace' as well"),
    (dict(trace="t.json", checkpoint_every=2, checkpoint_dir="d"),
     "checkpoint"),
    (dict(trace="t.json", flood_k=2), "flood_k"),
    (dict(trace="t.json", epoch_replay=False), "epoch_replay"),
    (dict(trace="t.json", flood_backend="numpy"), "round-synchronous"),
    (dict(trace="t.json", drain=True), "always drain"),
    (dict(trace="t.json", method="dzsgd", churn=ChurnSchedule.leave_rejoin(
        [1], 1, 2)), "cannot combine churn"),
])
def test_trace_config_rejections(kw, match):
    kw.setdefault("method", "seedflood")
    with pytest.raises(ValueError, match=match):
        validate_config(_cfg(**kw))


def test_trace_must_match_swarm_size():
    with pytest.raises(ValueError, match="covers 2 clients"):
        run(_cfg(method="seedflood", trace=TraceSet.constant(2)))


def test_trace_json_dict_accepted_by_run():
    trace = TraceSet.constant(4).to_json()
    r = run(_cfg(method="seedflood", trace=trace))
    assert len(r.loss_curve) == 3
    assert np.isfinite(r.loss_curve).all()
