"""Churn-tolerant flooding (DESIGN.md §6): dynamic topology mutations,
anti-entropy recovery, bitset-engine equivalence with the per-message
reference, staleness bounds under failures, and runner-level
rejoin-then-converge (SeedFlood recovers; gossip degrades)."""
import numpy as np
import pytest

from repro.core import flood
from repro.core.messages import Message, MESSAGE_BYTES
from repro.topology import graphs
from repro.topology.dynamic import (ChurnEvent, ChurnSchedule,
                                    DynamicTopology)

ENGINES = [flood.FloodNetwork, flood.VectorFloodNetwork]


def _inject_all(net, step=0):
    for i in range(net.n):
        if net.active_mask()[i]:
            net.inject(i, Message(seed=1000 + i + 10_000 * step, coef=0.5,
                                  origin=i, step=step))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_event_validation():
    with pytest.raises(ValueError):
        ChurnEvent(0, "explode")
    with pytest.raises(ValueError):
        ChurnEvent(-1, "leave", nodes=(0,))
    with pytest.raises(ValueError):
        ChurnEvent(0, "leave")                  # no nodes
    with pytest.raises(ValueError):
        ChurnEvent(0, "partition", groups=((0, 1),))  # one group


def test_leave_rejoin_schedule():
    s = ChurnSchedule.leave_rejoin([2, 5], leave_at=3, rejoin_at=7)
    assert [e.kind for e in s.events] == ["leave", "join"]
    assert s.events_at(3)[0].nodes == (2, 5)
    assert s.events_at(4) == []
    assert s.horizon == 7
    with pytest.raises(ValueError):
        ChurnSchedule.leave_rejoin([0], 5, 5)


def test_random_churn_deterministic_and_consistent():
    a = ChurnSchedule.random_churn(16, 60, rate=0.08, seed=3,
                                   max_concurrent=3)
    b = ChurnSchedule.random_churn(16, 60, rate=0.08, seed=3,
                                   max_concurrent=3)
    assert a.events == b.events
    assert len(a) > 0
    # replay: every leave is eventually matched by a join, never more than
    # max_concurrent offline, and everyone is back online at the horizon
    offline = set()
    for ev in a.events:
        if ev.kind == "leave":
            assert ev.nodes[0] not in offline
            offline.add(ev.nodes[0])
        else:
            assert ev.kind == "join" and ev.nodes[0] in offline
            offline.discard(ev.nodes[0])
        assert len(offline) <= 3
    assert not offline


def test_dynamic_topology_mutations():
    topo = DynamicTopology(graphs.ring(8))
    assert topo.effective_diameter() == 4
    topo.fail_link(0, 1)                    # ring -> path: diameter doubles
    assert topo.effective_diameter() == 7
    assert 1 not in topo.neighbors()[0]
    topo.restore_link(0, 1)
    assert topo.effective_diameter() == 4

    topo.leave(3)
    assert not topo.is_active(3)
    assert topo.neighbors()[3] == []
    assert 3 not in topo.neighbors()[2]
    with pytest.raises(ValueError):
        topo.leave(3)                       # double leave
    assert topo.join(3) == 2                # lowest-id live neighbour
    with pytest.raises(ValueError):
        topo.join(3)                        # double join


def test_partition_and_heal_cut_exactly_the_cross_edges():
    topo = DynamicTopology(graphs.meshgrid(16))   # 4x4 grid
    left = [i for i in range(16) if i % 4 < 2]
    right = [i for i in range(16) if i % 4 >= 2]
    cut = topo.partition([left, right])
    assert len(cut) == 4                    # one column boundary, 4 rows
    assert not topo.is_connected()
    assert sorted(topo.heal()) == sorted(cut)
    assert topo.is_connected()


# ---------------------------------------------------------------------------
# flood under churn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_dropout_drops_frontier_and_rejoin_recovers(engine):
    net = engine(graphs.meshgrid(16))
    _inject_all(net)
    # node 5 departs before any flooding: its own fresh message rides only in
    # its frontier, which the departure drops — the message is lost for now
    net.apply_churn([ChurnEvent(0, "leave", nodes=(5,))])
    net.full_flood()
    assert net.coverage((5, 0)) == 1
    for i in range(16):
        if i != 5:
            assert net.coverage((i, 0)) == 15   # everyone online got them
    # rejoin: anti-entropy runs across each of node 5's four revived edges,
    # pulling the 15 missed messages in and pushing its lost message out
    report = net.apply_churn([ChurnEvent(1, "join", nodes=(5,))])
    assert report.syncs == 4                # deg(5) on the 4x4 grid
    assert report.transferred == 16 + 3     # 15 in + (5,0) out to each nbr
    catch = net.drain_catchup()
    assert len(catch[5]) == 15
    net.full_flood()
    for i in range(16):
        assert net.coverage((i, 0)) == 16
    assert net.ledger.n_syncs == 4
    assert net.ledger.sync_bytes > 16 * MESSAGE_BYTES


@pytest.mark.parametrize("engine", ENGINES)
def test_partition_heal_refloods_missed_messages(engine):
    net = engine(graphs.meshgrid(16))
    groups = [list(range(8)), list(range(8, 16))]
    net.apply_churn([ChurnEvent(0, "partition", groups=[tuple(g) for g in groups])])
    _inject_all(net)
    net.full_flood()
    assert net.coverage((0, 0)) == 8        # flood stays within the island
    assert net.coverage((12, 0)) == 8
    net.apply_churn([ChurnEvent(1, "heal")])
    net.full_flood()
    for i in range(16):
        assert net.coverage((i, 0)) == 16


@pytest.mark.parametrize("engine", ENGINES)
def test_rejoin_bridges_disconnected_survivor_components(engine):
    """A vertex cut leaves {1,2,3} and {5,6,7} flooding independently; the
    rejoining bridge nodes must anti-entropy across *every* revived edge,
    otherwise one component's messages are silently lost forever."""
    net = engine(graphs.ring(8))
    net.apply_churn([ChurnEvent(0, "leave", nodes=(0, 4))])
    _inject_all(net, step=1)                # both islands flood their own
    net.full_flood()
    assert net.coverage((1, 1)) == 3 and net.coverage((5, 1)) == 3
    net.apply_churn([ChurnEvent(1, "join", nodes=(0, 4))])
    net.full_flood()
    for origin in (1, 2, 3, 5, 6, 7):       # every survivor message is
        assert net.coverage((origin, 1)) == 8   # everywhere, bridges included


@pytest.mark.parametrize("engine", ENGINES)
def test_offline_client_rejects_inject(engine):
    net = engine(graphs.ring(6))
    net.apply_churn([ChurnEvent(0, "leave", nodes=(2,))])
    with pytest.raises(ValueError):
        net.inject(2, Message(seed=7, coef=1.0, origin=2, step=0))


def test_staleness_bound_holds_under_link_failure():
    """Delayed flooding with k hops/iteration still covers within
    ⌈D_eff/k⌉ iterations of the *current* (degraded) topology."""
    n, k = 12, 2
    net = flood.FloodNetwork(graphs.ring(n))
    net.apply_churn([ChurnEvent(0, "link_down", edges=((0, n - 1),))])
    D_eff = net.diameter
    assert D_eff == n - 1                   # ring minus an edge is a path
    bound = flood.staleness_bound(D_eff, k)
    net.inject(0, Message(seed=9, coef=1.0, origin=0, step=0))
    iters = 0
    while net.coverage((0, 0)) < n:
        net.rounds(k)
        iters += 1
        assert iters <= bound
    assert iters <= bound


# ---------------------------------------------------------------------------
# bitset engine == reference engine, churn included
# ---------------------------------------------------------------------------

def _uid_sets(fresh):
    return [{m.uid for m in f} for f in fresh]


@pytest.mark.parametrize("topo,n", [("ring", 8), ("meshgrid", 16),
                                    ("torus", 16), ("star", 9)])
def test_vector_engine_matches_reference_static(topo, n):
    a = flood.FloodNetwork(graphs.make(topo, n))
    b = flood.VectorFloodNetwork(graphs.make(topo, n))
    _inject_all(a)
    _inject_all(b)
    assert _uid_sets(a.full_flood()) == _uid_sets(b.full_flood())
    la, lb = a.ledger, b.ledger
    assert (la.total_bytes, la.n_messages, la.rounds) == \
           (lb.total_bytes, lb.n_messages, lb.rounds)


def test_vector_engine_matches_reference_under_churn_script():
    """Same scripted run — injections, partial floods, leaves, link
    failures, rejoins — produces identical seen-sets, coverage, catch-up
    payloads, and byte ledgers on both engines."""
    script = [
        ("inject", 0), ("rounds", 2),
        ("churn", ChurnEvent(0, "leave", nodes=(5,))),
        ("inject", 1), ("rounds", 2),
        ("churn", ChurnEvent(0, "link_down", edges=((0, 1),))),
        ("inject", 2), ("rounds", 1),
        ("churn", ChurnEvent(0, "join", nodes=(5,))),
        ("churn", ChurnEvent(0, "link_up", edges=((0, 1),))),
        ("rounds", 4),
    ]
    nets = [flood.FloodNetwork(graphs.meshgrid(16)),
            flood.VectorFloodNetwork(graphs.meshgrid(16))]
    for op, arg in script:
        results = []
        for net in nets:
            if op == "inject":
                _inject_all(net, step=arg)
                results.append(None)
            elif op == "rounds":
                results.append(_uid_sets(net.rounds(arg)))
            else:
                net.apply_churn([arg])
                results.append(_uid_sets(net.drain_catchup()))
        assert results[0] == results[1]
    a, b = nets
    for i in range(16):
        assert a.seen_uids(i) == b.seen_uids(i)
    assert (a.ledger.total_bytes, a.ledger.n_messages, a.ledger.rounds,
            a.ledger.sync_bytes, a.ledger.n_syncs) == \
           (b.ledger.total_bytes, b.ledger.n_messages, b.ledger.rounds,
            b.ledger.sync_bytes, b.ledger.n_syncs)


def test_rounds_arrays_matches_messages():
    net = flood.VectorFloodNetwork(graphs.ring(8))
    ref = flood.FloodNetwork(graphs.ring(8))
    _inject_all(net)
    _inject_all(ref)
    arr = net.rounds_arrays(10)
    msgs = ref.rounds(10)
    for i in range(8):
        assert sorted(arr[i][0].tolist()) == sorted(m.seed for m in msgs[i])
        np.testing.assert_allclose(sorted(arr[i][1].tolist()),
                                   sorted(m.coef for m in msgs[i]))


# ---------------------------------------------------------------------------
# runner-level: rejoin-then-converge
# ---------------------------------------------------------------------------

def _run_cfg(**kw):
    from repro.dtrain.runner import DTrainConfig, sim_arch
    base = dict(n_clients=4, topology="ring", steps=6, lr=1e-2, batch_size=4,
                subcge_rank=8, local_iters=2,
                arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    base.update(kw)
    return DTrainConfig(**base)


def test_seedflood_rejoin_reconverges_and_gossip_degrades():
    from repro.dtrain.runner import run
    churn = ChurnSchedule.leave_rejoin([2], leave_at=2, rejoin_at=4)
    sf = run(_run_cfg(method="seedflood", churn=churn))
    # after anti-entropy catch-up, every client's params coincide again
    assert sf.consensus_error < 1e-9
    assert sf.extra["n_syncs"] >= 1
    dz = run(_run_cfg(method="dzsgd", churn=churn))
    assert dz.consensus_error > max(sf.consensus_error * 100, 1e-8)


def test_seedflood_backends_agree_under_churn():
    from repro.dtrain.runner import run
    churn = ChurnSchedule.leave_rejoin([2], leave_at=2, rejoin_at=4)
    py = run(_run_cfg(method="seedflood", churn=churn, flood_backend="python"))
    vec = run(_run_cfg(method="seedflood", churn=churn, flood_backend="numpy"))
    np.testing.assert_allclose(py.loss_curve, vec.loss_curve,
                               rtol=1e-4, atol=1e-6)
    assert py.total_bytes == vec.total_bytes
    assert vec.consensus_error < 1e-9


def test_churn_rejected_by_static_only_methods():
    from repro.dtrain.runner import run
    churn = ChurnSchedule.leave_rejoin([1], 1, 2)
    for method in ("gossip_sr", "central_zo"):
        with pytest.raises(ValueError):
            run(_run_cfg(method=method, churn=churn))
