"""Decentralized runtime integration tests: SeedFlood == centralized ZO
under full flooding, perfect consensus, byte-ledger ordering, delayed
flooding, LoRA baselines."""
import numpy as np
import pytest

from repro.dtrain.runner import DTrainConfig, run, sim_arch


def _cfg(**kw):
    base = dict(n_clients=4, topology="ring", steps=3, lr=1e-2, batch_size=4,
                subcge_rank=8, local_iters=2,   # gossip rounds fire in-test
                arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    base.update(kw)
    return DTrainConfig(**base)


def test_seedflood_equals_central_zo_stepwise():
    """Full flooding with identical seeds/batches reproduces centralized
    n-perturbation ZO exactly (up to float association)."""
    ra = run(_cfg(method="seedflood"))
    rb = run(_cfg(method="central_zo"))
    np.testing.assert_allclose(ra.loss_curve, rb.loss_curve,
                               rtol=1e-4, atol=1e-5)


def test_seedflood_perfect_consensus():
    r = run(_cfg(method="seedflood", steps=5))
    assert r.consensus_error < 1e-10


def test_seedflood_bytes_are_tiny_and_exact():
    r = run(_cfg(method="seedflood", steps=5))
    # each step floods 4 messages over a 4-ring: per directed edge at most
    # 4 msgs × 8 B; 5 steps × 2·|E|=8 directed edges
    assert r.total_bytes <= 5 * 8 * 4 * 8
    assert r.total_bytes > 0


def test_ledger_ordering_matches_paper():
    """bytes: dsgd ≫ dsgd_lora ≫ seedflood (paper Fig. 1 ordering)."""
    rs = run(_cfg(method="seedflood", steps=4))
    rl = run(_cfg(method="dsgd_lora", steps=4))
    rd = run(_cfg(method="dsgd", steps=4))
    assert rd.total_bytes > rl.total_bytes > rs.total_bytes
    assert rd.total_bytes / max(rs.total_bytes, 1) > 1e3


def test_delayed_flooding_diverges_then_converges():
    """k=1 on a ring: clients see stale messages, so per-client params differ
    transiently, but every message still arrives (bounded staleness)."""
    r = run(_cfg(method="seedflood", flood_k=1, steps=6, n_clients=6))
    assert r.extra["n_messages"] > 0
    # staleness bound D/k = 3: all messages injected by step 2 must have
    # arrived by the end; consensus error is small but nonzero mid-run —
    # final gap only from the last ⌈D/k⌉ steps' in-flight messages
    assert r.consensus_error < 1e-2


def test_dzsgd_and_choco_run():
    for m in ("dzsgd", "choco", "choco_lora", "dzsgd_lora"):
        r = run(_cfg(method=m, steps=2))
        assert np.isfinite(r.gmp) and r.total_bytes > 0


def test_gossip_sr_compute_blowup_measured():
    """§3.2: the strawman's reconstruction count grows superlinearly in t
    (history reweighting), while SeedFlood applies each message once."""
    r = run(_cfg(method="gossip_sr", steps=6, local_iters=2))
    # 4 clients × 6 steps = 24 messages; reconstructions must exceed that
    assert r.extra["reconstructions"] > 24


def test_subspace_momentum_runs_and_descends():
    """Beyond-paper: momentum in the r×r coefficient space (O(r²) state)
    must run and not blow up; convergence advantage is demonstrated in
    benchmarks (bench_output.txt momentum rows)."""
    import numpy as np
    r = run(_cfg(method="central_zo", steps=8, momentum=0.9, lr=1e-3))
    assert np.isfinite(r.loss_curve).all()
    assert np.isfinite(r.gmp)


def test_unknown_method_raises():
    with pytest.raises(KeyError):
        run(_cfg(method="nope"))
