"""End-to-end behaviour of the SeedFlood system (the paper's headline
claims, at simulator scale):

1. training decreases loss / beats zero-shot accuracy;
2. SeedFlood's communication is orders of magnitude below every baseline;
3. consensus is perfect and topology-invariant;
4. delayed flooding with moderate k matches full flooding.
"""
import numpy as np
import pytest

from repro.dtrain.runner import DTrainConfig, run, sim_arch


def _cfg(**kw):
    # concentration=0.02 gives peaked class-conditional token distributions,
    # so the LM loss is reducible and 120 ZO steps visibly learn (GMP ~0.6
    # vs 0.25 chance); lr tuned — ZO diverges above ~1e-2 at this scale.
    from repro.data.synthetic import TaskConfig
    base = dict(n_clients=4, topology="ring", steps=120, lr=3e-3,
                batch_size=16, subcge_rank=32, subcge_tau=1000,
                arch=sim_arch(d_model=48, n_layers=2, n_heads=4, d_ff=96),
                task=TaskConfig(vocab=256, seq_len=16, concentration=0.02))
    base.update(kw)
    return DTrainConfig(**base)


@pytest.fixture(scope="module")
def seedflood_run():
    # n=8: the ZO step averages 8 two-point estimates, which this CPU/jax
    # build needs to clear chance within 120 steps (n=4 stalls at ~0.23)
    return run(_cfg(method="seedflood", n_clients=8))


def test_training_improves_over_zero_shot(seedflood_run):
    """4 classes -> ~0.25 zero-shot; training must clearly beat chance."""
    assert seedflood_run.gmp > 0.40


def test_loss_decreases(seedflood_run):
    c = seedflood_run.loss_curve
    assert np.mean(c[-8:]) < np.mean(c[:8])


def test_communication_hierarchy():
    """The Fig. 1 ordering at simulator scale: SeedFlood ≪ LoRA-gossip ≪
    full gossip, with SeedFlood at least 10^3× below full gossip."""
    dsgd = run(_cfg(method="dsgd", steps=10))
    lora = run(_cfg(method="dsgd_lora", steps=10))
    sf10 = run(_cfg(method="seedflood", steps=10))
    assert sf10.total_bytes < lora.total_bytes < dsgd.total_bytes
    assert dsgd.total_bytes / sf10.total_bytes > 1e3


def test_perfect_consensus_all_topologies():
    for topo in ("ring", "meshgrid", "star"):
        r = run(_cfg(method="seedflood", topology=topo, steps=6,
                     n_clients=8))
        assert r.consensus_error < 1e-9, topo


def test_delayed_flooding_moderate_k_matches_full():
    """§4.5: k ≥ 4 ≈ full flooding (here diameter=4 ring of 8)."""
    full = run(_cfg(method="seedflood", n_clients=8, steps=25))
    k4 = run(_cfg(method="seedflood", n_clients=8, steps=25, flood_k=4))
    assert abs(full.gmp - k4.gmp) < 0.15
    # k=4 on diameter-4 ring IS full flooding per iteration
    assert k4.consensus_error < 1e-9


def test_seedflood_comm_independent_of_model_size():
    small = run(_cfg(method="seedflood", steps=5,
                     arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64)))
    big = run(_cfg(method="seedflood", steps=5,
                   arch=sim_arch(d_model=128, n_layers=3, n_heads=4, d_ff=256)))
    assert small.total_bytes == big.total_bytes    # exact — seeds don't scale
    dsgd_small = run(_cfg(method="dsgd", steps=5,
                          arch=sim_arch(d_model=32, n_layers=1, n_heads=2,
                                        d_ff=64)))
    dsgd_big = run(_cfg(method="dsgd", steps=5,
                        arch=sim_arch(d_model=128, n_layers=3, n_heads=4,
                                      d_ff=256)))
    assert dsgd_big.total_bytes > 3 * dsgd_small.total_bytes
