"""Subspace-epoch-correct message replay (the ISSUE 2 bugfix) + the batched
jit-resident SeedFlood step.

A seed-scalar message reconstructs the sender's exact update only if the
receiver regenerates the subspace of the SENDER's τ-epoch.  These tests pin:

* unit level  — ``apply_messages_epoch`` matches the sender bitwise across a
  refresh boundary, while the legacy receiver-step replay provably differs;
* wire level  — payload matrices carry sender steps; coef-0 padding columns
  are exact no-ops;
* runner level — delayed flooding (k < D, τ < staleness) and churn outages
  that cross a τ boundary re-converge to consensus under the fix, and
  measurably diverge when ``epoch_replay=False`` pins the old behavior;
* batched path — the single-dispatch jit step coincides with the per-client
  reference path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flood, seeds as seedlib, subcge
from repro.core.messages import Message
from repro.core.subcge import SubCGEConfig
from repro.dtrain.runner import DTrainConfig, run, sim_arch
from repro.topology import graphs
from repro.topology.dynamic import ChurnEvent, ChurnSchedule


# ---------------------------------------------------------------------------
# unit level: apply_messages_epoch
# ---------------------------------------------------------------------------

CFG = SubCGEConfig(rank=5, refresh_period=10, eps=1e-3)


def _params():
    return {
        "blk": {"w": jnp.zeros((3, 16, 24)), "bias": jnp.zeros((24,))},
        "emb": jnp.zeros((64, 16)),
    }


def _meta(params):
    return subcge.infer_meta(
        params, n_batch_dims_fn=lambda p, l: 1 if p == "blk/w" else 0)


def _leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_epoch_slots_unique_padded():
    steps = np.array([[0, 3, -1], [12, 19, 9]])
    slots = subcge.epoch_slots(steps, CFG)          # epochs {0, 10}
    assert slots.dtype == np.int32
    assert sorted(slots[slots >= 0].tolist()) == [0, 10]
    assert slots.shape[0] == 2                      # pow2, no pad needed
    three = subcge.epoch_slots(np.array([0, 10, 20]), CFG)
    assert three.shape[0] == 4 and three[3] == subcge.EPOCH_PAD


def test_single_epoch_equals_apply_messages():
    """With every sender step in one τ-window, the epoch path degenerates to
    the plain vectorized aggregation, bitwise."""
    params = _params()
    meta = _meta(params)
    seeds_k = jnp.asarray([11, 22, 33], jnp.uint32)
    coefs = jnp.asarray([0.5, -1.5, 2.0], jnp.float32)
    steps = jnp.asarray([3, 7, 9], jnp.int32)       # all in epoch 0
    sub = subcge.subspace_at_step(meta, CFG, 0, 3)
    want = subcge.apply_messages(params, meta, CFG, sub, seeds_k, coefs)
    got = subcge.apply_messages_epoch(
        params, meta, CFG, 0, seeds_k, coefs, steps,
        jnp.asarray(subcge.epoch_slots(np.asarray(steps), CFG)))
    _leaves_equal(got, want)


def test_replay_matches_sender_across_refresh_bitwise():
    """THE bug: a message sent at t=8 (epoch 0) replayed at t=13 (epoch 1)
    must reproduce the sender's applied update exactly.  The epoch-aware
    replay is bitwise-identical to the sender; the legacy receiver-step
    replay applies a different subspace and visibly diverges."""
    params = _params()
    meta = _meta(params)
    t_send, t_recv = 8, 13
    seed = jnp.asarray(seedlib.client_seeds(0, t_send, 4)[2:3])
    coef = jnp.asarray([0.37], jnp.float32)

    sender = subcge.apply_messages(
        params, meta, CFG, subcge.subspace_at_step(meta, CFG, 0, t_send),
        seed, coef)
    replay = subcge.apply_messages_epoch(
        params, meta, CFG, 0, seed, coef, jnp.asarray([t_send], jnp.int32),
        jnp.asarray(subcge.epoch_slots(np.asarray([t_send]), CFG)))
    _leaves_equal(replay, sender)

    # the old step=t_recv replay reconstructs under the wrong (U, V)
    legacy = subcge.apply_messages(
        params, meta, CFG, subcge.subspace_at_step(meta, CFG, 0, t_recv),
        seed, coef)
    gap = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in [(legacy["emb"], sender["emb"]),
                           (legacy["blk"]["w"], sender["blk"]["w"])])
    assert gap > 1e-3


def test_mixed_epoch_batch_equals_per_epoch_groups():
    """One batch spanning two τ-windows == applying each window's group under
    its own subspace (any grouping — the update is additive per message)."""
    params = _params()
    meta = _meta(params)
    seeds_k = jnp.asarray([5, 6, 7, 8], jnp.uint32)
    coefs = jnp.asarray([1.0, -2.0, 0.5, 3.0], jnp.float32)
    steps = jnp.asarray([4, 17, 9, 12], jnp.int32)  # epochs {0, 10}
    got = subcge.apply_messages_epoch(
        params, meta, CFG, 0, seeds_k, coefs, steps,
        jnp.asarray(subcge.epoch_slots(np.asarray(steps), CFG)))
    grouped = params
    for lo in (0, 10):
        sel = np.asarray((np.asarray(steps) // 10) * 10 == lo)
        sub = subcge.subspace_at_step(meta, CFG, 0, lo)
        grouped = subcge.apply_messages(
            grouped, meta, CFG, sub, jnp.asarray(np.asarray(seeds_k)[sel]),
            jnp.asarray(np.asarray(coefs)[sel]))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(grouped)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_padding_columns_are_exact_noops():
    params = _params()
    meta = _meta(params)
    seeds_k = np.asarray([11, 22, 33], np.uint32)
    coefs = np.asarray([0.5, -1.5, 2.0], np.float32)
    steps = np.asarray([3, 14, 25], np.int32)
    epochs = jnp.asarray(subcge.epoch_slots(steps, CFG))
    bare = subcge.apply_messages_epoch(
        params, meta, CFG, 0, jnp.asarray(seeds_k), jnp.asarray(coefs),
        jnp.asarray(steps), epochs)
    sds, cfs, stp = flood.pad_payloads([(seeds_k, coefs, steps)], minimum=8)
    padded = subcge.apply_messages_epoch(
        params, meta, CFG, 0, jnp.asarray(sds[0]), jnp.asarray(cfs[0]),
        jnp.asarray(stp[0]), epochs)
    _leaves_equal(padded, bare)


# ---------------------------------------------------------------------------
# wire level: payloads carry sender steps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", [flood.FloodNetwork,
                                    flood.VectorFloodNetwork])
def test_rounds_arrays_carry_sender_steps(engine):
    net = engine(graphs.ring(6))
    for step in (0, 1):
        for i in range(6):
            net.inject(i, Message(seed=100 * step + i, coef=0.5, origin=i,
                                  step=step))
        net.rounds(1)   # one hop: step-0 messages still in flight at inject 1
    sds, cfs, stp = net.rounds_padded(10)
    assert stp.shape == sds.shape == cfs.shape
    live = cfs != 0.0
    assert set(np.unique(stp[live])) <= {0, 1}
    assert (stp[~live] == flood.STEP_PAD).all()
    # each live entry's step matches the step encoded in its seed
    assert (sds[live] // 100 == stp[live]).all()


def test_drain_catchup_arrays_format():
    net = flood.FloodNetwork(graphs.meshgrid(16))
    for i in range(16):
        if i != 5:
            net.inject(i, Message(seed=1000 + i, coef=0.5, origin=i, step=7))
    net.apply_churn([ChurnEvent(0, "leave", nodes=(5,))])
    net.full_flood()
    net.apply_churn([ChurnEvent(1, "join", nodes=(5,))])
    catch = net.drain_catchup_arrays()
    sds, cfs, stp = catch[5]
    assert len(sds) == 15 and (stp == 7).all() and (cfs == 0.5).all()


# ---------------------------------------------------------------------------
# runner level: cross-epoch staleness re-converges only under the fix
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(method="seedflood", n_clients=6, topology="ring", steps=8,
                lr=1e-2, batch_size=4, subcge_rank=8, local_iters=2,
                arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    base.update(kw)
    return DTrainConfig(**base)


def test_delayed_flooding_across_refresh_coincides_only_with_epoch_replay():
    """flood_k=1 on a 6-ring (D=3, staleness ≤ 3) with τ=2 < staleness:
    most messages are replayed in a later τ-window than they were sent.
    After draining, every client has applied the identical message multiset,
    each under its sender's epoch — consensus to float-noise.  Pinning the
    legacy receiver-step replay reconstructs wrong perturbations and leaves
    clients orders of magnitude apart."""
    fixed = run(_cfg(flood_k=1, subcge_tau=2, drain=True))
    assert fixed.consensus_error < 1e-7
    buggy = run(_cfg(flood_k=1, subcge_tau=2, drain=True, epoch_replay=False))
    assert buggy.consensus_error > 1e-4
    assert buggy.consensus_error > 1e4 * max(fixed.consensus_error, 1e-12)


def test_churn_outage_across_refresh_coincides_only_with_epoch_replay():
    """A client offline across a τ boundary receives anti-entropy catch-up
    from older epochs; replaying it under the rejoin-time subspace (the old
    behavior) permanently forks that client."""
    churn = ChurnSchedule.leave_rejoin([2], leave_at=1, rejoin_at=5)
    fixed = run(_cfg(subcge_tau=3, churn=churn, drain=True))
    assert fixed.extra["n_syncs"] >= 1
    assert fixed.consensus_error < 1e-7
    buggy = run(_cfg(subcge_tau=3, churn=churn, drain=True,
                     epoch_replay=False))
    assert buggy.consensus_error > 1e-4


def test_full_outage_keeps_loss_finite_and_carries_previous():
    """Satellite bugfix: a churn event taking EVERY client offline used to
    make the loss log np.mean of an empty slice (NaN + RuntimeWarning)."""
    churn = ChurnSchedule([
        ChurnEvent(2, "leave", nodes=(0, 1, 2, 3, 4, 5)),
        ChurnEvent(4, "join", nodes=(0, 1, 2, 3, 4, 5))])
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        r = run(_cfg(steps=6, churn=churn))
    assert np.isfinite(r.loss_curve).all()
    assert r.loss_curve[2] == r.loss_curve[1]   # carried through the outage
    assert r.loss_curve[3] == r.loss_curve[1]
    assert r.consensus_error < 1e-7


# ---------------------------------------------------------------------------
# batched jit step == per-client reference
# ---------------------------------------------------------------------------

def test_batched_step_matches_per_client_reference():
    """One fused dispatch over the stacked client axis reproduces the
    per-client unstack/apply/restack loop at n=8 within float32 round-off
    (atol 1e-6 for one full estimate→update→replay step).  Longer horizons
    amplify that round-off through the ZO estimator — covered separately."""
    kw = dict(n_clients=8, steps=1)
    a = run(_cfg(**kw))
    b = run(_cfg(**kw, batched_step=False))
    np.testing.assert_allclose(a.loss_curve, b.loss_curve, rtol=0, atol=1e-6)
    for x, y in zip(jax.tree.leaves(a.extra["final_stacked"]),
                    jax.tree.leaves(b.extra["final_stacked"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)
    # cross-epoch delayed flooding: one extra step of ZO noise amplification
    kw = dict(n_clients=8, steps=2, flood_k=1, subcge_tau=2)
    a = run(_cfg(**kw))
    b = run(_cfg(**kw, batched_step=False))
    for x, y in zip(jax.tree.leaves(a.extra["final_stacked"]),
                    jax.tree.leaves(b.extra["final_stacked"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_batched_step_tracks_reference_over_long_horizon():
    """Over more steps the ZO estimator amplifies float32 round-off
    ((lp-lm)/2ε ≈ 500× per step), so long-horizon agreement is statistical:
    same loss trajectory at the tolerance the central-oracle test uses."""
    a = run(_cfg(steps=8))
    b = run(_cfg(steps=8, batched_step=False))
    np.testing.assert_allclose(a.loss_curve, b.loss_curve,
                               rtol=1e-4, atol=1e-4)
    assert a.total_bytes == b.total_bytes
