"""The kernel_backend knob end-to-end: the interpret backend drives the real
Pallas lowerings through the full training stack and must match the bitwise
jnp oracle path within fp32 tolerance (ISSUE 5 acceptance).

ZO runs are chaotic — alpha=(lp-lm)/2ε amplifies fp32 round-off — so two
numerically-different-but-correct implementations drift to ~1e-5 within a
few steps; the run-level comparisons use short horizons and fp32-scale
tolerances, not bitwise equality (which only the jnp path guarantees).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.core import subcge
from repro.core.subcge import SubCGEConfig
from repro.dtrain.runner import DTrainConfig, run, sim_arch, validate_config
from repro.models import params as plib
from repro.models import transformer as tf
from repro.models.perturb import nest_subspace, sample_pert

ARCH = sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64)


def _leaves_close(a, b, **kw):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **kw)


# ---------------------------------------------------------------------------
# the acceptance run: 8 clients, delayed flooding k < D, across a τ boundary
# ---------------------------------------------------------------------------

def _seedflood_cfg(backend: str) -> DTrainConfig:
    # ring of 8 has diameter 4; flood_k=1 keeps messages in flight across
    # the τ=2 refresh boundaries, so the epoch-grouped (E, K) replay layout
    # (and its fused kernel path) is genuinely exercised; drain flushes the
    # tail so both runs end at the same delivered-message set.
    return DTrainConfig(
        method="seedflood", n_clients=8, topology="ring", steps=4,
        lr=1e-2, batch_size=2, subcge_rank=4, subcge_tau=2, flood_k=1,
        drain=True, arch=ARCH, kernel_backend=backend)


def test_seedflood_interpret_matches_jnp_full_run():
    r_jnp = run(_seedflood_cfg("jnp"))
    r_int = run(_seedflood_cfg("interpret"))
    np.testing.assert_allclose(r_jnp.loss_curve, r_int.loss_curve,
                               rtol=1e-3, atol=1e-5)
    _leaves_close(r_jnp.extra["final_stacked"], r_int.extra["final_stacked"],
                  rtol=1e-3, atol=5e-4)
    # both runs flood identical message sets — byte ledgers must agree exactly
    assert r_jnp.total_bytes == r_int.total_bytes


# ---------------------------------------------------------------------------
# the perturbed forward: Bundle dense / dense_t / expert_dense dispatch
# ---------------------------------------------------------------------------

def _pert_loss(arch, backend, seed=7):
    spec = tf.arch_spec(arch)
    params = plib.init_params(spec, 0)
    meta = plib.subcge_meta(spec)
    scfg = SubCGEConfig(rank=4, refresh_period=50, kernel_backend=backend)
    sub = nest_subspace(subcge.subspace_at_step(meta, scfg, 3, 0))
    pert = sample_pert(meta, scfg, jnp.uint32(seed), scfg.eps)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, arch.vocab)}
    if arch.frontend is not None:
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (2, arch.frontend.n_embeds, arch.frontend.embed_dim))
    return float(tf.lm_loss(arch, params, batch, sub=sub, pert=pert,
                            kernel_backend=backend))


def test_perturbed_lm_loss_interpret_matches_jnp():
    # sim arch ties embeddings -> covers dense (mlp/attn), dense_t (logits)
    a = _pert_loss(ARCH, "jnp")
    b = _pert_loss(ARCH, "interpret")
    assert np.isfinite(b)
    np.testing.assert_allclose(a, b, rtol=1e-4)


def test_moe_perturbed_lm_loss_interpret_matches_jnp():
    # reduced MoE arch -> covers the batched per-expert rank-1 variant
    arch = archs.reduced(archs.get("kimi-k2-1t-a32b"))
    a = _pert_loss(arch, "jnp")
    b = _pert_loss(arch, "interpret")
    assert np.isfinite(b)
    np.testing.assert_allclose(a, b, rtol=1e-4)


# ---------------------------------------------------------------------------
# epoch-grouped replay + momentum fold through the kernel layer
# ---------------------------------------------------------------------------

def test_apply_messages_epoch_interpret_matches_jnp():
    arch = ARCH
    spec = tf.arch_spec(arch)
    params = plib.init_params(spec, 0)
    meta = plib.subcge_meta(spec)
    K = 8
    seeds = jnp.arange(1, K + 1, dtype=jnp.uint32)
    coefs = jnp.linspace(-1e-3, 1e-3, K, dtype=jnp.float32)
    steps = jnp.asarray([0, 3, 9, 10, 11, 19, 20, 25], jnp.int32)  # 4 epochs
    outs = {}
    for backend in ("jnp", "interpret"):
        scfg = SubCGEConfig(rank=5, refresh_period=10, kernel_backend=backend)
        epochs = jnp.asarray(subcge.epoch_slots(np.asarray(steps), scfg))
        assert epochs.shape[0] == 4
        outs[backend] = subcge.apply_messages_epoch(
            params, meta, scfg, 0, seeds, coefs, steps, epochs)
    _leaves_close(outs["jnp"], outs["interpret"], rtol=1e-4, atol=1e-5)


def test_central_zo_momentum_interpret_matches_jnp():
    def cfg(backend):
        return DTrainConfig(method="central_zo", n_clients=4, steps=2,
                            lr=1e-2, batch_size=2, subcge_rank=4,
                            momentum=0.9, arch=ARCH, kernel_backend=backend)
    r_jnp = run(cfg("jnp"))
    r_int = run(cfg("interpret"))
    np.testing.assert_allclose(r_jnp.loss_curve, r_int.loss_curve,
                               rtol=1e-3, atol=1e-5)
    _leaves_close(r_jnp.extra["final_params"], r_int.extra["final_params"],
                  rtol=1e-3, atol=5e-4)


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_validate_config_rejects_unknown_backend():
    cfg = DTrainConfig(method="seedflood", kernel_backend="cuda")
    with pytest.raises(ValueError, match="kernel_backend"):
        validate_config(cfg)


def test_validate_config_rejects_backend_on_non_subcge_methods():
    # dsgd never touches the SubCGE kernels — a non-default knob would be
    # silently ignored, which validate_config treats as a config error
    cfg = DTrainConfig(method="dsgd", kernel_backend="interpret")
    with pytest.raises(ValueError, match="kernel_backend"):
        validate_config(cfg)
    validate_config(DTrainConfig(method="dsgd"))  # default passes


def test_default_backend_is_jnp_off_tpu():
    from repro.kernels import ops
    if jax.default_backend() != "tpu":
        assert ops.resolve_backend("auto") == "jnp"
        assert SubCGEConfig().backend() == "jnp"


def test_scfg_backend_override():
    scfg = SubCGEConfig(kernel_backend="interpret")
    assert scfg.backend() == "interpret"
    assert scfg.backend("jnp") == "jnp"
