"""Gossip baselines: mixing matrices, consensus decay, compression."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip
from repro.topology import graphs


@pytest.mark.parametrize("topo,n", [("ring", 8), ("meshgrid", 16), ("star", 6)])
def test_metropolis_weights_doubly_stochastic(topo, n):
    W = graphs.metropolis_weights(graphs.make(topo, n))
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert (W >= -1e-12).all()


def test_spectral_gap_orders_topologies():
    """Denser graphs mix faster: gap(complete) > gap(meshgrid) > gap(ring)."""
    n = 16
    gaps = {t: graphs.spectral_gap(graphs.metropolis_weights(graphs.make(t, n)))
            for t in ("ring", "meshgrid", "complete")}
    assert gaps["complete"] > gaps["meshgrid"] > gaps["ring"] > 0


def test_mix_reduces_consensus_error():
    rng = np.random.default_rng(0)
    x0 = rng.normal(size=(8, 32)).astype(np.float32)
    stacked = {"w": jnp.asarray(x0)}
    W = graphs.metropolis_weights(graphs.ring(8))
    e0 = float(gossip.consensus_error(stacked))
    for _ in range(5):
        stacked = gossip.mix(stacked, W)
    e1 = float(gossip.consensus_error(stacked))
    assert e1 < e0 * 0.9
    # mean is preserved by doubly-stochastic mixing
    np.testing.assert_allclose(np.asarray(stacked["w"]).mean(axis=0),
                               x0.mean(axis=0), atol=1e-5)
    for _ in range(200):
        stacked = gossip.mix(stacked, W)
    assert float(gossip.consensus_error(stacked)) < 1e-6


def test_topk_compress_density():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64, 64)), jnp.float32)
    c = gossip.topk_compress(x, density=0.01)
    nz = int((np.asarray(c) != 0).sum())
    k = max(1, int(64 * 64 * 0.01))
    assert nz <= k + 8            # ties may add a few
    # kept entries are the largest-magnitude ones
    kept = np.abs(np.asarray(c)[np.asarray(c) != 0])
    dropped = np.abs(np.asarray(x)[np.asarray(c) == 0])
    assert kept.min() >= dropped.max() - 1e-6


def test_choco_round_surrogates_track_params():
    """With repeated rounds and a fixed target, surrogates converge to the
    params (error feedback works)."""
    rng = np.random.default_rng(2)
    params = {"w": jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)}
    W = graphs.metropolis_weights(graphs.ring(4))
    state = gossip.choco_init({"w": jnp.zeros_like(params["w"])})
    p = params
    err0 = float(jnp.mean((state.x_hat["w"] - p["w"]) ** 2))
    cons0 = float(gossip.consensus_error(p))
    for _ in range(60):
        p, state = gossip.choco_round(p, state, W, density=0.05,
                                      consensus_lr=0.5)
    err = float(jnp.mean((state.x_hat["w"] - p["w"]) ** 2))
    cons = float(gossip.consensus_error(p))
    assert err < 0.5 * err0          # surrogates track the params
    assert cons < 0.25 * cons0       # compressed gossip still reaches consensus
