"""Roofline machinery: HLO collective parsing (incl. while-trip-count
correction), analytic cost model vs XLA cost_analysis on unrolled programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis as ra
from repro.roofline import cost_model
from repro.configs import archs
from repro.configs.base import INPUT_SHAPES


def test_shape_bytes_parser():
    assert ra._shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert ra._shape_bytes("f32[16]") == 64
    assert ra._shape_bytes("(f32[4,4], u32[2])") == 64 + 8
    assert ra._shape_bytes("token[]") == 0


def test_parse_collectives_synthetic():
    hlo = """
HloModule m

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64] parameter(0)
  %ar = f32[64]{0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %ag = f32[64]{0} all-gather(%ar), replica_groups=[32,8]<=[256], dimensions={0}
}
"""
    stats = ra.parse_collectives(hlo)
    assert stats.count == 2
    # all-reduce: 2*(15/16)*256B; all-gather: (7/8)*256B
    np.testing.assert_allclose(stats.total_bytes,
                               2 * 15 / 16 * 256 + 7 / 8 * 256)


def test_hlo_cost_analysis_undercounts_while_bodies():
    """Documents WHY the analytic model exists: scan bodies count once."""
    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    def f_unroll(x, w):
        c = x
        for i in range(8):
            c = jnp.tanh(c @ w[i])
        return c

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    costs = {}
    for name, f in [("scan", f_scan), ("unroll", f_unroll)]:
        c = jax.jit(f).lower(x, w).compile().cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        costs[name] = c["flops"]
    assert costs["unroll"] == pytest.approx(8 * costs["scan"], rel=0.01)


def test_cost_model_matches_xla_on_unrolled_dense():
    """Analytic forward FLOPs ≈ XLA cost_analysis on an unrolled reduced
    dense model (within 10%)."""
    from repro.models import transformer as tf
    from repro.models import params as plib

    cfg = archs.reduced(archs.get("tinyllama-1.1b"), d_model=128)
    params = plib.init_params(tf.arch_spec(cfg), 0)
    B, T = 4, 64
    toks = jax.ShapeDtypeStruct((B, T), jnp.int32)

    def fwd(params, tokens):
        logits, _, _ = tf.forward(cfg, params, {"tokens": tokens})
        return logits

    c = jax.jit(fwd).lower(params, toks).compile().cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0]
    xla_flops = c["flops"]
    # reduced configs have 1-rep groups -> scan of length 1 -> no undercount
    analytic = cost_model.forward_cost(cfg, B, T, T, causal=True, db=4).flops
    assert analytic == pytest.approx(xla_flops, rel=0.15)


def test_step_cost_scales_sanely():
    cfg = archs.get("qwen2-72b")
    shp = INPUT_SHAPES["train_4k"]
    c_train = cost_model.step_cost(cfg, shp, "train")
    # ZO train ≈ 2 forwards ≈ 4·N·D within attention/update overhead
    ND4 = 4 * 72.7e9 * shp.global_batch * shp.seq
    assert 0.8 * ND4 < c_train.flops < 1.6 * ND4

    c_dec = cost_model.step_cost(cfg, INPUT_SHAPES["decode_32k"], "decode")
    ND2 = 2 * 72.7e9 * 128
    assert 0.8 * ND2 < c_dec.flops < 2.5 * ND2


def test_moe_cost_counts_active_not_total():
    cfg = archs.get("kimi-k2-1t-a32b")
    shp = INPUT_SHAPES["train_4k"]
    c = cost_model.step_cost(cfg, shp, "train")
    tokens = shp.global_batch * shp.seq
    total_4nd = 4 * 1.04e12 * tokens
    active_4nd = 4 * 32e9 * tokens
    assert c.flops < 0.15 * total_4nd        # nowhere near dense-equivalent
    assert c.flops > 0.5 * active_4nd        # but at least active-scale


def test_roofline_dominant_term():
    r = ra.roofline_terms(flops=1e18, bytes_accessed=1e12,
                          collective_bytes=1e12, chips=256, model_flops=8e17)
    assert r.dominant == "compute"
    assert r.useful_ratio == pytest.approx(0.8)
    r2 = ra.roofline_terms(1e12, 1e12, 1e15, 256)
    assert r2.dominant == "collective"


def test_sliding_window_reduces_decode_cost():
    shp = INPUT_SHAPES["long_500k"]
    cfg = archs.get("qwen2-72b")
    full = cost_model.step_cost(cfg, shp, "decode")
    sw = cost_model.step_cost(cfg.with_sliding_window(4096), shp, "decode")
    assert sw.flops < full.flops
    assert sw.bytes < full.bytes
