"""Shared-randomness primitives: the reconstructibility guarantees."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeds


def test_client_seed_unique_per_step_client():
    got = set()
    for t in range(50):
        for i in range(64):
            got.add(int(seeds.client_seed(7, t, i)))
    assert len(got) == 50 * 64


def test_message_key_deterministic():
    s = seeds.client_seed(3, 11, 5)
    k1 = seeds.message_key(s)
    k2 = seeds.message_key(seeds.client_seed(3, 11, 5))
    assert jnp.array_equal(jax.random.key_data(k1), jax.random.key_data(k2))


def test_leaf_key_path_dependence():
    k = jax.random.PRNGKey(0)
    a = seeds.leaf_key(k, "g0/s0/wq")
    b = seeds.leaf_key(k, "g0/s0/wk")
    assert not jnp.array_equal(jax.random.key_data(a), jax.random.key_data(b))


def test_path_hash_stable_across_processes():
    # blake2s, not python hash(): must be identical on every client
    assert seeds.path_hash("embed/tok") == seeds.path_hash("embed/tok")
    assert seeds.path_hash("embed/tok") < 2 ** 31


def test_coord_sample_range_and_shape():
    i, j = seeds.coord_sample(jax.random.PRNGKey(1), (3, 5), rank=7)
    assert i.shape == (3, 5) and j.shape == (3, 5)
    assert int(i.min()) >= 0 and int(i.max()) < 7
    assert int(j.min()) >= 0 and int(j.max()) < 7


def test_subspace_key_depends_on_refresh_step():
    a = seeds.subspace_key(1, 0, "w")
    b = seeds.subspace_key(1, 1000, "w")
    assert not jnp.array_equal(jax.random.key_data(a), jax.random.key_data(b))


def test_gaussian_like_reconstruction():
    """The core wire property: a perturbation is reproducible from its seed
    anywhere, bitwise."""
    s = seeds.client_seed(0, 5, 2)
    z1 = seeds.gaussian_like(seeds.leaf_key(seeds.message_key(s), "w"), (32, 16))
    z2 = seeds.gaussian_like(seeds.leaf_key(seeds.message_key(s), "w"), (32, 16))
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_tree_paths_and_map_with_paths():
    tree = {"a": {"b": jnp.zeros(2), "c": jnp.ones(3)}, "d": jnp.ones(1)}
    paths = seeds.tree_paths(tree)
    assert set(paths) == {"a/b", "a/c", "d"}
    seen = []
    seeds.map_with_paths(lambda p, l: seen.append(p) or l, tree)
    assert set(seen) == set(paths)
