"""Tier-1 gate: the repo itself must be sfcheck-clean.

``python -m repro.analysis src tests benchmarks examples`` and this test
check the same thing; the test keeps the invariant enforced for anyone
running only pytest.  Every finding is either fixed or carries a
``# sfcheck: noqa[SF0xx] -- why`` suppression — SF000 (reported here
like any other code) rejects suppressions without a justification.
"""
import pathlib

from repro.analysis.engine import check_paths

REPO = pathlib.Path(__file__).resolve().parents[1]
TREE = ["src", "tests", "benchmarks", "examples"]


def test_repo_tree_is_sfcheck_clean():
    paths = [REPO / d for d in TREE if (REPO / d).exists()]
    diagnostics = check_paths(paths, root=REPO)
    assert not diagnostics, (
        f"{len(diagnostics)} sfcheck violation(s) — fix them or suppress "
        "with a justified '# sfcheck: noqa[SF0xx] -- why':\n"
        + "\n".join(d.render() for d in diagnostics))
