"""Auxiliary coverage: mesh helpers, roofline table generation, dry-run
record schema, cost-model monotonicity."""
import glob
import json
import os

import pytest

from repro.configs import archs
from repro.configs.base import INPUT_SHAPES
from repro.launch import mesh as meshlib
from repro.roofline import cost_model

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def test_host_mesh_shapes():
    m = meshlib.make_host_mesh(1, 1)
    assert m.axis_names == ("data", "model")
    assert meshlib.mesh_size(m) == 1
    assert meshlib.data_axes(m) == ("data",)
    assert meshlib.data_extent(m) == 1
    with pytest.raises(ValueError):
        meshlib.make_host_mesh(64, 64)


def test_roofline_constants_are_v5e_class():
    assert meshlib.PEAK_FLOPS_BF16 == 197e12
    assert meshlib.HBM_BW == 819e9
    assert meshlib.ICI_BW == 50e9


@pytest.mark.skipif(not glob.glob(os.path.join(RESULTS, "*.json")),
                    reason="dry-run results not present")
def test_dryrun_records_schema_and_coverage():
    """The recorded baseline must cover all 10 archs × 4 shapes × 2 meshes
    with the §Roofline fields present."""
    recs = [json.load(open(f))
            for f in sorted(glob.glob(os.path.join(RESULTS, "*.json")))]
    ok = [r for r in recs if "error" not in r]
    combos = {(r["arch"], r["shape"], r["mesh"] if isinstance(r["mesh"], str)
               else "x".join(map(str, r["mesh"]))) for r in ok}
    assert len(combos) >= 80, f"only {len(combos)} dry-run records"
    for r in ok[:5]:
        roof = r["roofline"]
        for k in ("compute_s", "memory_s", "collective_s", "dominant",
                  "useful_ratio"):
            assert k in roof
        assert r["resident_bytes_per_device"] > 0
        assert r["chips"] in (256, 512)


@pytest.mark.skipif(not glob.glob(os.path.join(RESULTS, "*.json")),
                    reason="dry-run results not present")
def test_roofline_markdown_generates():
    from benchmarks import roofline_table
    recs = roofline_table.load(RESULTS)
    md = roofline_table.roofline_markdown(recs)
    assert md.count("\n") >= 40
    assert "dominant" in md
    rows = roofline_table.csv_rows(recs)
    assert len(rows) >= 80


def test_cost_model_monotonic_in_tokens():
    cfg = archs.get("tinyllama-1.1b")
    s4k = INPUT_SHAPES["train_4k"]
    half = cost_model.forward_cost(cfg, s4k.global_batch, s4k.seq // 2,
                                   s4k.seq // 2)
    full = cost_model.forward_cost(cfg, s4k.global_batch, s4k.seq, s4k.seq)
    assert full.flops > 1.9 * half.flops     # superlinear (attention)
    assert full.bytes > half.bytes


def test_cost_model_decode_is_memory_lean_on_ssm():
    """Attention-free decode reads params once; its bytes dwarf its flops."""
    cfg = archs.get("falcon-mamba-7b")
    c = cost_model.step_cost(cfg, INPUT_SHAPES["decode_32k"], "decode")
    intensity = c.flops / c.bytes
    assert intensity < 150                     # memory-bound regime


def test_fo_train_costs_more_than_zo():
    cfg = archs.get("qwen1.5-0.5b")
    zo_c = cost_model.step_cost(cfg, INPUT_SHAPES["train_4k"], "train")
    fo_c = cost_model.step_cost(cfg, INPUT_SHAPES["train_4k"], "train_dsgd")
    assert fo_c.flops > 1.3 * zo_c.flops       # 3 fwd-equiv vs 2 fwd + update
