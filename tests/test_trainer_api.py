"""Method × Transport plugin API: registry smoke, per-method config
validation, checkpoint/resume bitwise fidelity, RunResult.to_json."""
import json
import os

import jax
import numpy as np
import pytest

from repro.dtrain.methods import METHOD_SPECS
from repro.dtrain.runner import (DTrainConfig, METHODS, run, sim_arch,
                                 validate_config)
from repro.topology.dynamic import ChurnSchedule


def _cfg(**kw):
    base = dict(n_clients=4, topology="ring", steps=3, lr=1e-2, batch_size=4,
                subcge_rank=8, local_iters=2,
                arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    base.update(kw)
    return DTrainConfig(**base)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(METHODS))
def test_registry_entry_runs_three_steps(name):
    """Every METHODS entry is a runnable callable: 3 steps, finite losses,
    a labelled RunResult."""
    r = METHODS[name](_cfg(method=name))
    assert len(r.loss_curve) == 3
    assert np.isfinite(r.loss_curve).all()
    assert r.method
    assert np.isfinite(r.gmp)


def test_registry_and_specs_agree():
    assert set(METHODS) == set(METHOD_SPECS)


# ---------------------------------------------------------------------------
# per-method config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("field,value,bad_method,good_method", [
    ("momentum", 0.9, "dsgd", "central_zo"),
    ("choco_density", 0.1, "seedflood", "choco"),
    ("flood_k", 2, "dzsgd", "seedflood"),
    ("flood_backend", "numpy", "gossip_sr", "seedflood"),
    ("batched_step", False, "central_zo", "seedflood"),
    ("epoch_replay", False, "dsgd_lora", "seedflood"),
    ("drain", True, "choco_lora", "seedflood"),
    ("lora_r", 4, "dsgd", "dsgd_lora"),
    ("lora_alpha", 8.0, "dzsgd", "choco_lora"),
])
def test_silently_ignored_fields_are_rejected(field, value, bad_method,
                                              good_method):
    with pytest.raises(ValueError, match=field):
        validate_config(_cfg(method=bad_method, **{field: value}))
    validate_config(_cfg(method=good_method, **{field: value}))


def test_rejection_reaches_run():
    with pytest.raises(ValueError, match="momentum"):
        run(_cfg(method="dsgd", momentum=0.9))


def test_default_values_pass_everywhere():
    for name in METHODS:
        validate_config(_cfg(method=name))


def test_checkpoint_fields_must_come_paired():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        validate_config(_cfg(method="seedflood", checkpoint_every=2))
    with pytest.raises(ValueError, match="checkpoint_every"):
        validate_config(_cfg(method="seedflood", checkpoint_dir="ckpts"))


def test_eval_cadence_is_uniform_across_methods():
    """Deliberate difference from the monolith (whose run_central_zo /
    run_gossip_sr ignored eval_every and always returned acc_curve=[]): the
    unified Trainer honors the eval cadence for EVERY method."""
    r = run(_cfg(method="central_zo", steps=2, eval_every=1))
    assert [t for t, _ in r.acc_curve] == [1, 2]
    assert r.consensus_error == 0.0     # single model: consensus is trivial


# ---------------------------------------------------------------------------
# RunResult.to_json
# ---------------------------------------------------------------------------

def test_compile_wall_s_split_from_step_samples():
    """The first executed step pays jit compilation; it lands in
    RunResult.compile_wall_s and extra["step_wall_s"] keeps only the
    steady-state samples, so bench medians need no slicing."""
    r = run(_cfg(method="seedflood", steps=3))
    assert r.compile_wall_s > 0.0
    assert len(r.extra["step_wall_s"]) == 2
    assert all(s >= 0.0 for s in r.extra["step_wall_s"])
    assert "compile_wall_s" in r.to_json()


def test_to_json_is_serializable_and_drops_param_trees():
    r = run(_cfg(method="seedflood", steps=2, eval_every=1))
    d = r.to_json()
    s = json.dumps(d)                       # must not raise
    assert "final_stacked" not in d["extra"]
    assert isinstance(d["gmp"], float)
    assert isinstance(d["total_bytes"], (int, float))
    back = json.loads(s)
    assert back["loss_curve"] == r.loss_curve


def test_to_json_coerces_hostile_extras():
    from repro.dtrain.api import RunResult
    import jax.numpy as jnp
    r = RunResult(method="x", gmp=np.float32(0.5), loss_curve=[np.float64(1.0)],
                  acc_curve=[(np.int64(1), np.float32(0.25))],
                  bytes_per_edge=np.float32(8.0), total_bytes=np.int64(64),
                  consensus_error=jnp.float32(0.0), wall_s=1.0,
                  extra={"arr": jnp.arange(3), "np": np.arange(2),
                         "scalar": np.float32(2.0), "final_params": {"w": 1},
                         "nested": {"curve": [(1, np.float32(0.5))]}})
    d = r.to_json()
    json.dumps(d)                            # must not raise
    assert d["extra"]["arr"] == [0, 1, 2]
    assert d["extra"]["scalar"] == 2.0
    assert "final_params" not in d["extra"]


# ---------------------------------------------------------------------------
# checkpoint / resume (bitwise)
# ---------------------------------------------------------------------------

def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_resume_bitwise(tmp_path, tag, **kw):
    """Run 6 steps straight vs 3 + resume(3); everything but wall-clock must
    coincide bitwise."""
    ckdir = os.path.join(tmp_path, tag)
    full = run(_cfg(steps=6, **kw))
    half = run(_cfg(steps=6, checkpoint_every=3, checkpoint_dir=ckdir, **kw))
    path = os.path.join(ckdir, "step000003.npz")
    assert os.path.exists(path)
    resumed = run(_cfg(steps=6, resume_from=path, **kw))
    for r in (half, resumed):
        assert r.loss_curve == full.loss_curve
        assert r.total_bytes == full.total_bytes
        assert r.consensus_error == full.consensus_error
        assert r.gmp == full.gmp
        assert r.acc_curve == full.acc_curve
    for key in ("final_stacked", "final_params"):
        if key in full.extra:
            _leaves_equal(full.extra[key], resumed.extra[key])
    return full, resumed


def test_seedflood_resume_bitwise_across_tau_epoch(tmp_path):
    """THE satellite: delayed flooding (k=1 < D) keeps messages in flight
    across the checkpoint, and τ=2 puts the resume mid-subspace-window —
    the resumed run must still bitwise-match the uninterrupted one
    (frontiers, seen-sets, ledger and epoch state all restored)."""
    _assert_resume_bitwise(tmp_path, "sf", method="seedflood", n_clients=6,
                           flood_k=1, subcge_tau=2, drain=True)


def test_seedflood_resume_bitwise_with_churn_and_vector_backend(tmp_path):
    """Checkpoint lands while a client is OFFLINE (leave at 2, rejoin at 4 >
    checkpoint step 3): the restored topology overlay + bitset engine state
    must replay the rejoin + anti-entropy identically."""
    churn = ChurnSchedule.leave_rejoin([2], leave_at=2, rejoin_at=4)
    _assert_resume_bitwise(tmp_path, "sfc", method="seedflood", n_clients=6,
                           churn=churn, flood_backend="numpy", subcge_tau=3)


def test_gossip_and_choco_resume_bitwise(tmp_path):
    _assert_resume_bitwise(tmp_path, "dz", method="dzsgd", eval_every=3)
    # choco: the surrogate copies x̂ are transport state and must survive
    _assert_resume_bitwise(tmp_path, "ch", method="choco")


def test_central_zo_momentum_resume_bitwise(tmp_path):
    """Velocity buffers (r×r per leaf) are method state; τ=4 puts a refresh
    (velocity reset) after the resume point."""
    _assert_resume_bitwise(tmp_path, "cz", method="central_zo", momentum=0.9,
                           subcge_tau=4)


def test_gossip_sr_resume_bitwise(tmp_path):
    """Coefficient histories and applied-ledgers round-trip through JSON in
    insertion order (delta re-application order is part of the math)."""
    _assert_resume_bitwise(tmp_path, "sr", method="gossip_sr")


def test_resume_rejects_method_mismatch(tmp_path):
    ckdir = os.path.join(tmp_path, "mm")
    run(_cfg(method="seedflood", checkpoint_every=3, checkpoint_dir=ckdir))
    path = os.path.join(ckdir, "step000003.npz")
    with pytest.raises(ValueError, match="seedflood"):
        run(_cfg(method="central_zo", resume_from=path))
