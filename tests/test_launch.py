"""Launch layer: step builders execute correctly on a 1×1 host mesh, and the
real dry-run entry point works end-to-end in a subprocess (512 placeholder
devices, production 16×16 mesh)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.configs.base import InputShape
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh


SMALL = InputShape("small", seq=32, global_batch=4, kind="train")


def _exec(kind, name="tinyllama-1.1b", shape=SMALL):
    cfg = archs.reduced(archs.get(name))
    mesh = make_host_mesh(1, 1)
    pod = steplib.PodConfig(param_dtype=jnp.float32, rank=4, n_clients=2)
    fn, example, in_sh, out_sh = steplib.build_step(kind, cfg, shape, mesh, pod)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        args = jax.tree.map(
            lambda s: (jnp.zeros(s.shape, s.dtype)
                       if jnp.issubdtype(s.dtype, jnp.integer)
                       else 0.01 * jnp.ones(s.shape, s.dtype)),
            example)
        return jitted(*args), cfg


def test_seedflood_train_step_executes():
    (new_params, metrics), cfg = _exec("train")
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["alpha_rms"]))
    for leaf in jax.tree.leaves(new_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_train_step_updates_are_consensus_deterministic():
    """Same inputs -> bitwise-same update (the all-clients-identical
    invariant that lets the pod keep a single θ)."""
    (p1, _), _ = _exec("train")
    (p2, _), _ = _exec("train")
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_buffer_mode_matches_fold_mode():
    """Paper App. A buffer mode (A accumulated, W+UAV^T on the fly) must be
    step-equivalent to fold mode: effective weights identical after steps."""
    import numpy as np
    from repro.core import subcge
    from repro.models import params as plib
    from repro.models import transformer as tfm

    cfg = archs.reduced(archs.get("tinyllama-1.1b"))
    mesh = make_host_mesh(1, 1)
    spec = tfm.arch_spec(cfg)
    meta = plib.subcge_meta(spec)

    results = {}
    for mode in ("fold", "buffer"):
        pod = steplib.PodConfig(param_dtype=jnp.float32, rank=4, n_clients=2,
                                apply_mode=mode, lr=1e-2, tau=1000)
        fn, example, in_sh, out_sh = steplib.build_step("train", cfg, SMALL,
                                                        mesh, pod)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            state = jax.tree.map(
                lambda s: (jnp.zeros(s.shape, s.dtype)
                           if jnp.issubdtype(s.dtype, jnp.integer)
                           else 0.01 * jnp.ones(s.shape, s.dtype)),
                example)[0]
            if mode == "buffer":  # A-buffers start at zero, not 0.01
                state = (state[0], jax.tree.map(jnp.zeros_like, state[1]))
            batch = {"tokens": jnp.zeros((2, 2, 32), jnp.int32)}
            for step in range(3):
                state, metrics = jitted(state, batch, jnp.int32(step))
        if mode == "buffer":
            params, bufs = state
            scfg = pod.subcge()
            sub = subcge.subspace_at_step(meta, scfg, pod.base_seed, 2)
            state = subcge.fold_buffers(params, meta, sub, bufs)
        results[mode] = state

    for a, b in zip(jax.tree.leaves(results["fold"]),
                    jax.tree.leaves(results["buffer"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_dsgd_train_step_executes():
    (new_params, metrics), _ = _exec("train_dsgd")
    assert np.isfinite(float(metrics["loss"]))


def test_prefill_and_decode_steps_execute():
    shape = InputShape("s", seq=32, global_batch=4, kind="prefill")
    (last_logits, cache), cfg = _exec("prefill", shape=shape)
    assert last_logits.shape == (4, cfg.vocab)
    dshape = InputShape("d", seq=32, global_batch=4, kind="decode")
    (logits, new_cache), cfg = _exec("decode", shape=dshape)
    assert logits.shape == (4, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_train_batch_shapes_respect_client_split():
    cfg = archs.reduced(archs.get("qwen1.5-0.5b"))
    mesh = make_host_mesh(1, 1)
    pod = steplib.PodConfig(n_clients=2)
    batch, _ = steplib.train_inputs(cfg, SMALL, mesh, pod)
    assert batch["tokens"].shape == (2, 2, 32)   # n_clients × per-client × seq


def test_frontend_arch_input_specs_include_embeds():
    cfg = archs.reduced(archs.get("internvl2-26b"))
    mesh = make_host_mesh(1, 1)
    pod = steplib.PodConfig(n_clients=2, param_dtype=jnp.float32)
    batch, _ = steplib.train_inputs(cfg, SMALL, mesh, pod)
    assert "embeds" in batch
    n_emb = cfg.frontend.n_embeds
    assert batch["embeds"].shape == (2, 2, n_emb, cfg.frontend.embed_dim)
    assert batch["tokens"].shape[-1] == 32 - n_emb


@pytest.mark.slow
def test_dryrun_subprocess_production_mesh():
    """The real thing: 512 placeholder devices, 16×16 mesh, one arch×shape."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = os.path.join("/tmp", "dryrun_test.json")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen1.5-0.5b",
         "--shape", "decode_32k", "--out", out],
        capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stderr[-2000:]
    rec = json.load(open(out))
    assert rec["chips"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["resident_bytes_per_device"] > 0
