"""Optional-hypothesis shim for the tier-1 environment.

The container running the tier-1 suite does not ship ``hypothesis``.
Property-test modules import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly: when hypothesis is installed (CI, dev
boxes) they are the real thing; when it is missing, ``given`` marks the
test skipped and the strategy namespace returns inert placeholders so
module-level decorator expressions still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 container
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
