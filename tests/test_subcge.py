"""SubCGE: subspace structure, canonical-coordinate perturbations, and the
O(n + r·d) vectorized aggregation (paper §3.4, eq. 9-10)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import subcge, zo
from repro.core.subcge import SubCGEConfig


def _params():
    return {
        "blk": {"w": jnp.zeros((3, 16, 24)), "scale": jnp.zeros((3, 16)),
                "bias": jnp.zeros((24,))},
        "moe": {"we": jnp.zeros((2, 4, 8, 12))},
        "emb": jnp.zeros((64, 16)),
    }


def _meta(params):
    def nb(path, leaf):
        if path == "blk/w":
            return 1
        if path == "blk/scale":
            return 1
        if path == "moe/we":
            return 2
        return 0
    return subcge.infer_meta(params, n_batch_dims_fn=nb)


CFG = SubCGEConfig(rank=5, refresh_period=10, eps=1e-3)


def test_meta_classification():
    params = _params()
    meta = _meta(params)
    assert meta["blk/w"].is_matrix and meta["blk/w"].batch_shape == (3,)
    assert not meta["blk/scale"].is_matrix          # stacked vector
    assert not meta["blk/bias"].is_matrix
    assert meta["moe/we"].is_matrix and meta["moe/we"].batch_shape == (2, 4)
    assert meta["emb"].is_matrix and meta["emb"].batch_shape == ()


def test_subspace_identical_across_clients():
    """Any client regenerating at the same (seed, step) gets bitwise-equal
    U/V — globally shared subspaces with zero communication."""
    meta = _meta(_params())
    s1 = subcge.subspace_at_step(meta, CFG, 42, 13)
    s2 = subcge.subspace_at_step(meta, CFG, 42, 17)    # same refresh window
    s3 = subcge.subspace_at_step(meta, CFG, 42, 23)    # next window
    for p in s1:
        np.testing.assert_array_equal(np.asarray(s1[p].U), np.asarray(s2[p].U))
    assert not np.array_equal(np.asarray(s1["emb"].U), np.asarray(s3["emb"].U))


def test_perturbation_is_canonical_rank1():
    """z_ℓ must be exactly U[:,i] V[:,j]^T for some (i,j) per instance."""
    params = _params()
    meta = _meta(params)
    sub = subcge.subspace_at_step(meta, CFG, 0, 0)
    z = subcge.materialize_z(params, meta, CFG, sub, jnp.uint32(99))
    zw = np.asarray(z["emb"])
    assert np.linalg.matrix_rank(zw) == 1
    U, V = np.asarray(sub["emb"].U), np.asarray(sub["emb"].V)
    # find the matching coordinate
    coords = subcge.sample_coords(meta, CFG, jnp.uint32(99))["emb"]
    want = np.outer(U[:, int(coords.i)], V[:, int(coords.j)])
    np.testing.assert_allclose(zw, want, rtol=1e-6)


def test_scatter_A_batched():
    i = jnp.array([[0, 1], [2, 1], [0, 1]])      # (K=3, B=2)
    j = jnp.array([[1, 1], [2, 1], [1, 0]])
    coefs = jnp.array([1.0, 10.0, 100.0])
    A = subcge.scatter_A(i, j, coefs, rank=3)
    assert A.shape == (2, 3, 3)
    assert float(A[0, 0, 1]) == 101.0            # k=0 and k=2 hit (0,(0,1))
    assert float(A[0, 2, 2]) == 10.0
    assert float(A[1, 1, 1]) == 11.0
    assert float(A[1, 1, 0]) == 100.0


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 9), st.integers(0, 2 ** 31 - 1))
def test_apply_messages_equals_sequential(K, seed0):
    """Vectorized aggregation (scatter + U A V^T) == replaying each message
    individually — the eq. 10 equivalence, property-tested."""
    params = _params()
    meta = _meta(params)
    sub = subcge.subspace_at_step(meta, CFG, 1, 0)
    seeds_k = jnp.asarray(
        np.random.default_rng(seed0).integers(0, 2 ** 31, size=K), jnp.uint32)
    coefs = jnp.asarray(np.random.default_rng(seed0 + 1).normal(size=K),
                        jnp.float32)
    fast = subcge.apply_messages(params, meta, CFG, sub, seeds_k, coefs)
    slow = params
    for s, c in zip(seeds_k, coefs):
        z = subcge.materialize_z(params, meta, CFG, sub, s)
        slow = zo.tree_add_scaled(slow, z, c)
    for a, b in zip(jax.tree.leaves(fast), jax.tree.leaves(slow)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_frozen_leaves_untouched():
    params = _params()
    meta = subcge.infer_meta(params, frozen_fn=lambda p: p == "emb")
    sub = subcge.subspace_at_step(meta, CFG, 0, 0)
    out = subcge.apply_messages(params, meta, CFG, sub,
                                jnp.asarray([5], jnp.uint32),
                                jnp.asarray([2.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(out["emb"]),
                                  np.asarray(params["emb"]))
    assert not np.array_equal(np.asarray(out["blk"]["w"]),
                              np.asarray(params["blk"]["w"]))


def test_buffer_mode_equals_direct_apply():
    """Appendix A: accumulate into A_ℓ, fold on demand == direct update."""
    params = _params()
    meta = _meta(params)
    # buffer path covers matrix leaves; restrict comparison to those
    sub = subcge.subspace_at_step(meta, CFG, 0, 0)
    seeds_k = jnp.asarray([11, 22, 33], jnp.uint32)
    coefs = jnp.asarray([0.5, -1.5, 2.0], jnp.float32)

    direct = subcge.apply_messages(params, meta, CFG, sub, seeds_k, coefs)
    bufs = subcge.zero_buffers(meta, CFG)
    bufs = subcge.accumulate_buffers(bufs, meta, CFG, seeds_k[:2], coefs[:2])
    bufs = subcge.accumulate_buffers(bufs, meta, CFG, seeds_k[2:], coefs[2:])
    folded = subcge.fold_buffers(params, meta, sub, bufs)
    for p in ("blk/w", "moe/we", "emb"):
        a = folded
        b = direct
        for k in p.split("/"):
            a, b = a[k], b[k]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_refresh_period_windows():
    assert int(subcge.refresh_step(0, CFG)) == 0
    assert int(subcge.refresh_step(9, CFG)) == 0
    assert int(subcge.refresh_step(10, CFG)) == 10
    assert int(subcge.refresh_step(25, CFG)) == 20
