"""repro.serve: continuous-batching decode over live seed-reconstructed
weights (DESIGN.md §10).

Three pinned oracles:

1. **Stub parity** — the paged continuous-batching server reproduces the
   monolithic ``launch/serve.py`` greedy token stream bitwise, including
   when the batch is squeezed through fewer slots than requests
   (eviction + free-list reuse + staggered admission).
2. **Live-update parity** — decoding while folding flood messages at
   decode-step boundaries equals offline-folding the same messages into
   the weights at the same boundaries and decoding monolithically —
   including a fold whose messages cross a τ-refresh boundary
   (epoch-grouped, sender-step rule).
3. **Churn replay** — a trainers+servers swarm with leave/rejoin churn on
   the virtual clock is a pure function of its script: running it twice
   gives identical token streams AND an identical byte ledger.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.base import InputShape
from repro.core.seeds import client_seed
from repro.core.subcge import SubCGEConfig
from repro.launch import steps as steplib
from repro.launch.mesh import make_host_mesh
from repro.models import params as plib
from repro.models import transformer as tf
from repro.serve import (DecodeServer, LiveUpdateBridge, PageAllocator,
                         Request, Scheduler, ServeConfig, ServeSwarmSim,
                         bucket_pages, pages_needed)
from repro.topology.dynamic import ChurnSchedule

B, PL, NEW = 4, 12, 4
CAP = PL + NEW


@pytest.fixture(scope="module")
def cfg():
    return archs.reduced(archs.get("tinyllama-1.1b"))


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


@pytest.fixture(scope="module")
def pod():
    return steplib.PodConfig(param_dtype=jnp.float32)


@pytest.fixture(scope="module")
def params(cfg):
    return plib.init_params(tf.arch_spec(cfg), 0, jnp.float32)


@pytest.fixture(scope="module")
def prompts(cfg):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(0), (B, PL), 0,
                                         cfg.vocab), np.int32)


def _monolithic_stream(cfg, mesh, pod, params, prompts, fold_at=None):
    """The exact launch/serve.py greedy loop (pre-paging): eager prefill over
    a monolithic cache, then jitted single-position decode.  ``fold_at``
    maps decode-step index -> params to switch to AT that step boundary
    (index 0 = before prefill) for the live-update oracle."""
    n_req = prompts.shape[0]
    dshape = InputShape("serve", CAP, n_req, "decode")
    decode, _, in_sh, out_sh = steplib.build_decode_step(cfg, dshape, mesh,
                                                         pod)
    fold_at = fold_at or {}
    with mesh:
        p = fold_at.get(0, params)
        cache = tf.init_cache(cfg, n_req, CAP, jnp.float32)
        logits, cache, _ = tf.forward(cfg, p, {"tokens": jnp.asarray(prompts)},
                                      cache=cache, pos=0)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        decode_j = jax.jit(decode, in_shardings=in_sh, out_shardings=out_sh)
        out = [tok]
        for i in range(NEW - 1):
            p = fold_at.get(i + 1, p)
            lg, cache = decode_j(p, cache, tok, jnp.int32(PL + i))
            tok = jnp.argmax(lg, axis=-1)[:, None]
            out.append(tok)
    return np.concatenate([np.asarray(t) for t in out], axis=1)


@pytest.fixture(scope="module")
def ref_stream(cfg, mesh, pod, params, prompts):
    return _monolithic_stream(cfg, mesh, pod, params, prompts)


# ---------------------------------------------------------------------------
# host-side units: page allocator, buckets, scheduler, config
# ---------------------------------------------------------------------------

def test_pages_needed_and_buckets():
    assert pages_needed(1, 4) == 1 and pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2 and pages_needed(16, 4) == 4
    assert bucket_pages(1, 8) == 1
    assert bucket_pages(3, 8) == 4          # pow2 round-up
    assert bucket_pages(5, 6) == 6          # capped at pages_per_req
    assert bucket_pages(0, 8) == 1


def test_page_allocator_reserve_release_reuse():
    a = PageAllocator(n_pages=8, page_size=4, max_batch=2, pages_per_req=4)
    assert a.dump == 8 and a.free_pages == 8
    p0 = a.alloc(0, 3)
    assert p0 == [0, 1, 2] and a.pages_in_use == 3
    assert list(a.table[0]) == [0, 1, 2, 8]     # tail holds the dump id
    with pytest.raises(ValueError):
        a.alloc(0, 1)                           # slot already occupied
    p1 = a.alloc(1, 4)
    assert p1 == [3, 4, 5, 6]
    assert not a.can_alloc(2) and a.can_alloc(1)
    with pytest.raises(ValueError):
        a.alloc(0, 2)                           # only 1 page free
    assert a.release(0) == [0, 1, 2]
    assert list(a.table[0]) == [8, 8, 8, 8]
    # freed pages are reused lowest-first, in the released order
    assert a.alloc(0, 2) == [0, 1]


def test_page_allocator_rejects_undersized_pool():
    with pytest.raises(ValueError):
        PageAllocator(n_pages=3, page_size=4, max_batch=1, pages_per_req=4)


def test_serve_config_validation():
    assert ServeConfig().pages_per_req == 128 // 16
    with pytest.raises(ValueError):
        ServeConfig(sampling="nucleus")
    with pytest.raises(ValueError):
        ServeConfig(max_seq=100, page_size=16)  # not a page multiple
    with pytest.raises(ValueError):
        ServeConfig(sampling="temperature", temperature=0.0)


def test_scheduler_fifo_admission_and_eviction():
    cfg = ServeConfig(max_batch=2, page_size=4, n_pages=4, max_seq=16)
    s = Scheduler(cfg)
    with pytest.raises(ValueError):             # over max_seq
        s.submit(Request(rid=9, prompt=np.arange(13), max_new=4))
    s.submit(Request(rid=0, prompt=np.arange(6), max_new=2))   # 2 pages
    s.submit(Request(rid=1, prompt=np.arange(6), max_new=2))   # 2 pages
    s.submit(Request(rid=2, prompt=np.arange(2), max_new=2))   # 1 page
    admitted = s.admit()
    # head-of-line blocking: rid 2 (1 page) must NOT jump rid 1's budget
    assert [r.rid for _, r in admitted] == [0, 1]
    assert s.alloc.free_pages == 0
    assert [r.rid for r in s.queue] == [2]
    assert s.decode_bucket() == 2               # pos 6 -> 7 positions -> 2pg
    # finishing rid 0 frees its pages; rid 2 admits into the freed slot
    s.record_emit(0, 5)
    assert s.slots[0] is not None               # one token still owed
    s.record_emit(0, 7)
    assert s.slots[0] is None and s.n_evicted == 1
    admitted = s.admit()
    assert [(i, r.rid) for i, r in admitted] == [(0, 2)]
    assert not s.done
    s.record_emit(1, 1)
    s.record_emit(1, 1)
    s.record_emit(0, 1)
    s.record_emit(0, 1)
    assert s.done


# ---------------------------------------------------------------------------
# oracle 1: paged continuous batching == monolithic greedy stream
# ---------------------------------------------------------------------------

def test_paged_server_matches_monolithic_stream(cfg, mesh, pod, params,
                                                prompts, ref_stream):
    serve = ServeConfig(max_batch=B, page_size=4, n_pages=16, max_seq=CAP)
    srv = DecodeServer(cfg, params, serve, mesh=mesh, pod=pod)
    for b in range(B):
        srv.submit(Request(rid=b, prompt=prompts[b], max_new=NEW))
    results = srv.run()
    np.testing.assert_array_equal(
        np.array([results[b] for b in range(B)]), ref_stream)
    st = srv.stats()
    assert st["evicted"] == B and st["prefills"] == 1


def test_staggered_slots_still_match_monolithic(cfg, mesh, pod, params,
                                                prompts, ref_stream):
    """4 requests through 2 slots: the second wave admits into pages the
    first wave freed — eviction, free-list reuse and a second prefill, all
    without perturbing any token."""
    serve = ServeConfig(max_batch=2, page_size=4, n_pages=8, max_seq=CAP)
    srv = DecodeServer(cfg, params, serve, mesh=mesh, pod=pod)
    for b in range(B):
        srv.submit(Request(rid=b, prompt=prompts[b], max_new=NEW))
    results = srv.run()
    np.testing.assert_array_equal(
        np.array([results[b] for b in range(B)]), ref_stream)
    st = srv.stats()
    assert st["prefills"] == 2 and st["evicted"] == B


def test_duplicate_rid_rejected(cfg, mesh, pod, params, prompts):
    serve = ServeConfig(max_batch=2, page_size=4, n_pages=8, max_seq=CAP)
    srv = DecodeServer(cfg, params, serve, mesh=mesh, pod=pod)
    srv.submit(Request(rid=0, prompt=prompts[0], max_new=1))
    with pytest.raises(ValueError):
        srv.submit(Request(rid=0, prompt=prompts[1], max_new=1))


def test_temperature_sampling_is_deterministic(cfg, mesh, pod, params,
                                               prompts):
    def stream(seed):
        serve = ServeConfig(max_batch=B, page_size=4, n_pages=16,
                            max_seq=CAP, sampling="temperature",
                            temperature=5.0, sample_seed=seed)
        srv = DecodeServer(cfg, params, serve, mesh=mesh, pod=pod)
        for b in range(B):
            srv.submit(Request(rid=b, prompt=prompts[b], max_new=NEW))
        return np.array([srv.run()[b] for b in range(B)])

    a, b = stream(0), stream(0)
    np.testing.assert_array_equal(a, b)         # same seed -> same stream
    assert ((0 <= a) & (a < cfg.vocab)).all()
    assert not np.array_equal(a, stream(1))     # T=5.0 is nearly uniform


# ---------------------------------------------------------------------------
# oracle 2: live-update fold parity (incl. τ-refresh boundary)
# ---------------------------------------------------------------------------

def _msg_batch(gseed, steps):
    steps = np.asarray(steps, np.int32)
    seeds = np.array([client_seed(gseed, int(s), i % 2)
                      for i, s in enumerate(steps)], np.uint32)
    return seeds, np.full(steps.shape, 0.05, np.float32), steps


def test_decode_under_live_updates_matches_offline_fold(cfg, mesh, pod,
                                                        params, prompts,
                                                        ref_stream):
    scfg = SubCGEConfig(rank=4, refresh_period=2, eps=1e-3)
    gseed = 7
    b1 = _msg_batch(gseed, [0, 0, 1, 1])        # epochs {0}: one slot
    b2 = _msg_batch(gseed, [1, 2, 2, 3])        # epochs {0, 2}: crosses τ=2

    # offline reference: fold the same batches into the weights at the same
    # step boundaries (same jitted epoch-grouped apply), decode monolithic
    ref_bridge = LiveUpdateBridge(cfg, scfg, gseed, node=0)
    ref_bridge.ingest_arrays(*b1)
    p1 = ref_bridge.fold(params)
    ref_bridge.ingest_arrays(*b2)
    p2 = ref_bridge.fold(p1)
    ref = _monolithic_stream(cfg, mesh, pod, params, prompts,
                             fold_at={0: p1, 2: p2})
    assert not np.array_equal(ref, ref_stream)  # folds must move tokens

    serve = ServeConfig(max_batch=B, page_size=4, n_pages=16, max_seq=CAP)
    bridge = LiveUpdateBridge(cfg, scfg, gseed, node=0)
    srv = DecodeServer(cfg, params, serve, mesh=mesh, pod=pod, bridge=bridge)
    for b in range(B):
        srv.submit(Request(rid=b, prompt=prompts[b], max_new=NEW))
    bridge.ingest_arrays(*b1)
    srv.step()                                  # fold b1 -> prefill+decode 1
    bridge.ingest_arrays(*b2)
    srv.step()                                  # fold b2 -> decode 2
    srv.step()                                  # decode 3
    assert srv.sched.done
    np.testing.assert_array_equal(
        np.array([srv.results[b] for b in range(B)]), ref)
    assert bridge.stats() == {"messages_folded": 8, "n_folds": 2,
                              "pending": 0}


def test_bridge_ingest_skips_inbox_padding():
    cfg = archs.reduced(archs.get("tinyllama-1.1b"))
    br = LiveUpdateBridge(cfg, SubCGEConfig(rank=4), 0, node=0)
    n = br.ingest_arrays(np.array([3, 0, 5], np.uint32),
                         np.array([0.1, 0.0, 0.2], np.float32),
                         np.array([0, -1, 2], np.int32))
    assert n == 2 and br.pending == 2           # the step=-1 row is padding


# ---------------------------------------------------------------------------
# oracle 3: churn replay determinism on the virtual clock
# ---------------------------------------------------------------------------

def test_churn_replay_is_deterministic(cfg):
    scfg = SubCGEConfig(rank=4, refresh_period=2, eps=1e-3)
    serve = ServeConfig(max_batch=2, page_size=4, n_pages=12, max_seq=20)
    sim_prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1),
                                                (4, 12), 0, cfg.vocab),
                             np.int32)

    def build():
        sim = ServeSwarmSim(cfg, scfg, serve, n_trainers=2, n_servers=2,
                            train_steps=6, global_seed=7,
                            churn=ChurnSchedule.leave_rejoin([3], 2, 4),
                            train_period=1.0, serve_period=0.5)
        for rid in range(4):
            sim.submit(2 if rid < 2 else 3,
                       Request(rid=rid, prompt=sim_prompts[rid], max_new=6))
        return sim

    a, b = build().run(), build().run()
    assert a["tokens"] == b["tokens"]
    assert a["ledger"] == b["ledger"]
    assert a["servers"] == b["servers"]
    # the churn actually bit: server 3 suspended mid-decode, re-prefilled
    # on rejoin, and caught its weights up through the flood
    assert a["servers"][3]["suspends"] == 2
    assert a["servers"][3]["prefills"] == 2
    assert a["servers"][3]["bridge"]["messages_folded"] > 0
    assert a["ledger"]["sync_bytes"] > 0        # anti-entropy was charged
    assert sorted(a["tokens"]) == [0, 1, 2, 3]
    assert all(len(t) == 6 for t in a["tokens"].values())


def test_churn_may_only_target_servers(cfg):
    scfg = SubCGEConfig(rank=4)
    serve = ServeConfig(max_batch=2, page_size=4, n_pages=8, max_seq=16)
    with pytest.raises(ValueError):
        ServeSwarmSim(cfg, scfg, serve, n_trainers=2, n_servers=1,
                      churn=ChurnSchedule.leave_rejoin([0], 1, 2))
