"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape,r", [
    ((128, 128), 8), ((256, 512), 32), ((384, 128), 16),
    ((3, 128, 256), 32), ((2, 4, 128, 128), 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_subcge_apply_kernel(shape, r, dtype):
    n, m = shape[-2:]
    ks = jax.random.split(jax.random.PRNGKey(sum(shape) + r), 4)
    W = jax.random.normal(ks[0], shape, dtype)
    U = jax.random.normal(ks[1], (n, r), jnp.float32)
    V = jax.random.normal(ks[2], (m, r), jnp.float32)
    A = jax.random.normal(ks[3], shape[:-2] + (r, r), jnp.float32)
    got = ops.subcge_apply(W, U, A, V, interpret=True)
    want = ref.subcge_apply(W, U, A, V)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 512, 128),
                                 (64, 384, 256), (512, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s", [0.0, 1e-3, -2.5])
def test_rank1_matmul_kernel(mkn, dtype, s):
    M, K, N = mkn
    ks = jax.random.split(jax.random.PRNGKey(M + K + N), 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    W = jax.random.normal(ks[1], (K, N), dtype)
    u = jax.random.normal(ks[2], (K,), jnp.float32)
    v = jax.random.normal(ks[3], (N,), jnp.float32)
    got = ops.rank1_matmul(x, W, u, v, s, interpret=True)
    want = ref.rank1_matmul(x, W, u, v, s)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol * 20)


def test_rank1_matmul_zero_scale_is_plain_matmul():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (128, 256))
    W = jax.random.normal(ks[1], (256, 128))
    u = jax.random.normal(ks[2], (256,))
    v = jax.random.normal(ks[3], (128,))
    got = ops.rank1_matmul(x, W, u, v, 0.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("btdn", [(1, 64, 128, 16), (2, 128, 128, 8),
                                  (1, 96, 256, 4)])
def test_selective_scan_kernel(btdn):
    B, T, D, N = btdn
    ks = jax.random.split(jax.random.PRNGKey(B * T + D), 4)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D, N)))
    bx = 0.1 * jax.random.normal(ks[1], (B, T, D, N))
    c = jax.random.normal(ks[2], (B, T, N))
    h0 = jax.random.normal(ks[3], (B, D, N))
    got_y, got_h = ops.selective_scan(a, bx, c, h0, interpret=True)
    want_y, want_h = ref.selective_scan(a, bx, c, h0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-4)


def test_selective_scan_kernel_matches_model_layer():
    """Kernel == the chunked associative scan used by models/layers.py."""
    from repro.models.layers import _ssm_chunked
    B, T, D, N = 2, 64, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D, N)))
    bx = 0.1 * jax.random.normal(ks[1], (B, T, D, N))
    h0 = jnp.zeros((B, D, N))
    c = jax.random.normal(ks[2], (B, T, N))
    y_k, h_k = ops.selective_scan(a, bx, c, h0, interpret=True)
    h_all, h_last = _ssm_chunked(a, bx, h0, chunk=16)
    y_ref = jnp.einsum("btdn,btn->btd", h_all, c)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_last),
                               rtol=1e-4, atol=1e-4)
