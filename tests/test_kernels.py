"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps,
the shared ``_tile`` helper, and the kernel_backend dispatch contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import KERNEL_BACKENDS
from repro.kernels import ops, ref  # sfcheck: noqa[SF006] -- this suite IS the oracle-parity gate; it needs the raw ref kernels


# ---------------------------------------------------------------------------
# _tile: one shared helper, bug-fixed (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def test_tile_320_256_regression():
    # the historical subcge_apply._tile returned 80 here, skipping the valid
    # 160 — pinned so the "largest admissible divisor" contract can't rot
    assert ops._tile(320, 256) == 160


@pytest.mark.parametrize("dim,target,want", [
    (128, 256, 128),    # whole dim fits
    (256, 256, 256),
    (512, 256, 256),    # aligned divisor at target
    (896, 256, 128),    # 128 divides 896; the larger 224 is unaligned
    (384, 256, 128),    # ditto: 192 is larger but unaligned
    (320, 256, 160),    # no aligned divisor -> genuinely largest
    (96, 256, 96),
    (7, 256, 7),
    (100, 64, 50),
    (1, 256, 1),
])
def test_tile_cases(dim, target, want):
    assert ops._tile(dim, target) == want


@pytest.mark.parametrize("dim", [1, 7, 96, 100, 320, 512, 896, 1000])
@pytest.mark.parametrize("target", [1, 128, 256, 512])
def test_tile_properties(dim, target):
    t = ops._tile(dim, target)
    assert 1 <= t <= max(1, min(dim, target))
    assert dim % t == 0
    # preference contract: if any multiple-of-128 divisor is admissible, the
    # result is one of them — and the largest such
    aligned = [d for d in range(1, min(dim, target) + 1)
               if dim % d == 0 and d % 128 == 0]
    if aligned:
        assert t == max(aligned)
    else:
        assert t == max(d for d in range(1, min(dim, target) + 1)
                        if dim % d == 0)


def test_tile_shared_by_all_kernel_modules():
    from repro.kernels import rank1_matmul, selective_scan, subcge_apply  # sfcheck: noqa[SF006] -- asserts the kernel modules share ops._tile
    assert subcge_apply._tile is ops._tile
    assert rank1_matmul._tile is ops._tile
    assert selective_scan._tile is ops._tile


# ---------------------------------------------------------------------------
# backend resolution: explicit, cached, no per-call sniffing
# ---------------------------------------------------------------------------

def test_resolve_backend_values():
    assert ops.resolve_backend("jnp") == "jnp"
    assert ops.resolve_backend("pallas") == "pallas"
    assert ops.resolve_backend("interpret") == "interpret"
    assert ops.resolve_backend("auto") in ("jnp", "pallas")
    with pytest.raises(ValueError):
        ops.resolve_backend("cuda")


def test_auto_resolution_is_cached(monkeypatch):
    # the "auto" meaning is frozen at first use: even if the platform sniff
    # were to change mid-process, already-resolved callers keep their path
    first = ops.resolve_backend("auto")
    monkeypatch.setattr(ops, "on_tpu", lambda: True)
    assert ops.resolve_backend("auto") == first


def test_default_backend_roundtrip():
    assert ops.get_default_backend() in KERNEL_BACKENDS
    prev = ops.set_default_backend("interpret")
    try:
        assert ops.get_default_backend() == "interpret"
        assert ops.resolve_backend() == "interpret"
    finally:
        ops.set_default_backend(prev)
    with pytest.raises(ValueError):
        ops.set_default_backend("nope")
    with ops.default_backend("jnp"):
        assert ops.resolve_backend() == "jnp"


def test_jnp_dispatch_is_bitwise_the_oracle():
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    W = jax.random.normal(ks[0], (96, 80))
    U = jax.random.normal(ks[1], (96, 8))
    V = jax.random.normal(ks[2], (80, 8))
    A = jax.random.normal(ks[3], (8, 8))
    got = ops.subcge_apply(W, U, A, V, backend="jnp")
    want = ref.subcge_apply(W, U, A, V)
    assert (np.asarray(got) == np.asarray(want)).all()


# ---------------------------------------------------------------------------
# subcge_apply: W += U A V^T  (instance/batch dims share U/V)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,r", [
    ((128, 128), 8), ((256, 512), 32), ((384, 128), 16),
    ((320, 896), 8),                      # non-divisible-by-256 dims
    ((320, 64), 4), ((96, 320), 2),       # odd tiles both axes, multiple ranks
    ((3, 128, 256), 32), ((2, 4, 128, 128), 8),
    ((2, 320, 96), 16),                   # batch dims x non-divisible dims
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_subcge_apply_kernel(shape, r, dtype):
    n, m = shape[-2:]
    ks = jax.random.split(jax.random.PRNGKey(sum(shape) + r), 4)
    W = jax.random.normal(ks[0], shape, dtype)
    U = jax.random.normal(ks[1], (n, r), jnp.float32)
    V = jax.random.normal(ks[2], (m, r), jnp.float32)
    A = jax.random.normal(ks[3], shape[:-2] + (r, r), jnp.float32)
    got = ops.subcge_apply(W, U, A, V, backend="interpret")
    want = ref.subcge_apply(W, U, A, V)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("E", [1, 2, 4])
@pytest.mark.parametrize("batch", [(), (3,)])
def test_subcge_apply_epochs_kernel(E, batch):
    n, m, r = 96, 320, 5
    ks = jax.random.split(jax.random.PRNGKey(E + len(batch)), 4)
    W = jax.random.normal(ks[0], batch + (n, m))
    U = jax.random.normal(ks[1], (E, n, r))
    V = jax.random.normal(ks[2], (E, m, r))
    A = jax.random.normal(ks[3], (E,) + batch + (r, r))
    got = ops.subcge_apply_epochs(W, U, A, V, backend="interpret")
    want = ref.subcge_apply_epochs(W, U, A, V)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_subcge_apply_epochs_matches_sequential_single_epoch_applies():
    # the rank-(E·r) block-diagonal fold == applying each epoch in turn
    n, m, r, E = 64, 80, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    W = jax.random.normal(ks[0], (n, m))
    U = jax.random.normal(ks[1], (E, n, r))
    V = jax.random.normal(ks[2], (E, m, r))
    A = jax.random.normal(ks[3], (E, r, r))
    seq = W
    for e in range(E):
        seq = ref.subcge_apply(seq, U[e], A[e], V[e])
    got = ops.subcge_apply_epochs(W, U, A, V, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                               rtol=1e-4, atol=1e-3)


def test_subcge_delta():
    n, m, r = 320, 96, 6
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    U = jax.random.normal(ks[0], (n, r))
    V = jax.random.normal(ks[1], (m, r))
    A = jax.random.normal(ks[2], (r, r))
    got = ops.subcge_delta(U, A, V, jnp.float32, backend="interpret")
    want = ref.subcge_delta(U, A, V, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# rank1_matmul family: the fused ZO dual forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 512, 128),
                                 (64, 384, 256), (512, 128, 512),
                                 (40, 320, 96), (24, 896, 320)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s", [0.0, 1e-3, -2.5])
def test_rank1_matmul_kernel(mkn, dtype, s):
    M, K, N = mkn
    ks = jax.random.split(jax.random.PRNGKey(M + K + N), 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    W = jax.random.normal(ks[1], (K, N), dtype)
    u = jax.random.normal(ks[2], (K,), jnp.float32)
    v = jax.random.normal(ks[3], (N,), jnp.float32)
    got = ops.rank1_matmul(x, W, u, v, s, backend="interpret")
    want = ref.rank1_matmul(x, W, u, v, s)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol * 20)


def test_rank1_matmul_zero_scale_is_plain_matmul():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (128, 256))
    W = jax.random.normal(ks[1], (256, 128))
    u = jax.random.normal(ks[2], (256,))
    v = jax.random.normal(ks[3], (128,))
    got = ops.rank1_matmul(x, W, u, v, 0.0, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ W),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("mno", [(40, 96, 320), (128, 128, 256),
                                 (64, 320, 896)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s", [1e-3, -0.7])
def test_rank1_matmul_t_kernel(mno, dtype, s):
    M, N, O = mno                 # x (M,N) @ W (O,N)^T -> (M,O)
    ks = jax.random.split(jax.random.PRNGKey(M + N + O), 4)
    x = jax.random.normal(ks[0], (M, N), dtype)
    W = jax.random.normal(ks[1], (O, N), dtype)
    u = jax.random.normal(ks[2], (O,), jnp.float32)
    v = jax.random.normal(ks[3], (N,), jnp.float32)
    got = ops.rank1_matmul_t(x, W, u, v, s, backend="interpret")
    want = ref.rank1_matmul_t(x, W, u, v, s)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol * 20)


def test_rank1_matmul_t_is_rank1_matmul_of_transpose():
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (32, 96))
    W = jax.random.normal(ks[1], (80, 96))
    u = jax.random.normal(ks[2], (80,))
    v = jax.random.normal(ks[3], (96,))
    a = ops.rank1_matmul_t(x, W, u, v, 1.3, backend="interpret")
    b = ops.rank1_matmul(x, W.T, v, u, 1.3, backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("ecnm", [(4, 24, 96, 64), (2, 128, 64, 320),
                                  (8, 16, 320, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rank1_matmul_expert_kernel(ecnm, dtype):
    E, C, n, m = ecnm
    ks = jax.random.split(jax.random.PRNGKey(E * C + n + m), 4)
    x = jax.random.normal(ks[0], (E, C, n), dtype)
    W = jax.random.normal(ks[1], (E, n, m), dtype)
    u = jax.random.normal(ks[2], (n, E), jnp.float32)
    v = jax.random.normal(ks[3], (m, E), jnp.float32)
    got = ops.rank1_matmul_expert(x, W, u, v, -0.3, backend="interpret")
    want = ref.rank1_matmul_expert(x, W, u, v, -0.3)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=tol, atol=tol * 20)


def test_rank1_kernels_accept_traced_scale():
    # the dual forward flips s = ±ε under jit — s must be traceable
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    x = jax.random.normal(ks[0], (16, 64))
    W = jax.random.normal(ks[1], (64, 32))
    u = jax.random.normal(ks[2], (64,))
    v = jax.random.normal(ks[3], (32,))

    @jax.jit
    def f(s):
        return ops.rank1_matmul(x, W, u, v, s, backend="interpret")

    np.testing.assert_allclose(np.asarray(f(0.5)),
                               np.asarray(ref.rank1_matmul(x, W, u, v, 0.5)),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("btdn", [(1, 64, 128, 16), (2, 128, 128, 8),
                                  (1, 96, 256, 4)])
def test_selective_scan_kernel(btdn):
    B, T, D, N = btdn
    ks = jax.random.split(jax.random.PRNGKey(B * T + D), 4)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D, N)))
    bx = 0.1 * jax.random.normal(ks[1], (B, T, D, N))
    c = jax.random.normal(ks[2], (B, T, N))
    h0 = jax.random.normal(ks[3], (B, D, N))
    got_y, got_h = ops.selective_scan(a, bx, c, h0, backend="interpret")
    want_y, want_h = ref.selective_scan(a, bx, c, h0)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-4)


def test_selective_scan_kernel_matches_model_layer():
    """Kernel == the chunked associative scan used by models/layers.py."""
    from repro.models.layers import _ssm_chunked
    B, T, D, N = 2, 64, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D, N)))
    bx = 0.1 * jax.random.normal(ks[1], (B, T, D, N))
    h0 = jnp.zeros((B, D, N))
    c = jax.random.normal(ks[2], (B, T, N))
    y_k, h_k = ops.selective_scan(a, bx, c, h0, backend="interpret")
    h_all, h_last = _ssm_chunked(a, bx, h0, chunk=16)
    y_ref = jnp.einsum("btdn,btn->btd", h_all, c)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_last),
                               rtol=1e-4, atol=1e-4)
