"""Zeroth-order estimators: unbiasedness on quadratics + convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zo


def test_two_point_exact_on_quadratic():
    """For f(θ)=½θᵀθ the symmetric estimator is exact for any ε:
    α = zᵀθ (no ε² term survives)."""
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    z = {"w": jnp.ones((2, 3))}
    loss = lambda p: 0.5 * jnp.sum(p["w"] ** 2)
    for eps in (1e-1, 1e-3):
        a = zo.two_point_alpha(loss, params, z, eps)
        np.testing.assert_allclose(float(a), float(jnp.sum(params["w"])),
                                   rtol=1e-3)


def test_alpha_approximates_directional_derivative():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (8, 8))
    params = {"w": jax.random.normal(jax.random.fold_in(key, 1), (8,))}
    loss = lambda p: jnp.sum(jnp.tanh(W @ p["w"]) ** 2)
    z = zo.mezo_z(params, jnp.uint32(7))
    # ε=1e-2: big enough to dodge f32 cancellation, truncation is O(ε²)
    a = zo.two_point_alpha(loss, params, z, 1e-2)
    want = float(jnp.vdot(jax.grad(loss)(params)["w"], z["w"]))
    np.testing.assert_allclose(float(a), want, rtol=3e-2)


def test_mezo_z_seed_reconstructible():
    params = {"a": jnp.zeros((4, 4)), "b": jnp.zeros(3)}
    z1 = zo.mezo_z(params, jnp.uint32(5))
    z2 = zo.mezo_z(params, jnp.uint32(5))
    z3 = zo.mezo_z(params, jnp.uint32(6))
    np.testing.assert_array_equal(np.asarray(z1["a"]), np.asarray(z2["a"]))
    assert not np.array_equal(np.asarray(z1["a"]), np.asarray(z3["a"]))


def test_zo_sgd_converges_on_quadratic():
    params = {"w": 3.0 * jnp.ones(16)}
    loss = lambda p: 0.5 * jnp.sum(p["w"] ** 2)
    for t in range(300):
        params, _ = zo.zo_sgd_step(loss, params, jnp.uint32(t), eps=1e-3,
                                   lr=5e-2)
    assert float(loss(params)) < 0.5 * 16 * 9 * 0.05


def test_mezo_apply_messages_matches_loop():
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros(4)}
    seeds = jnp.asarray([1, 2, 3, 4], jnp.uint32)
    coefs = jnp.asarray([0.1, -0.2, 0.3, -0.4], jnp.float32)
    fast = zo.mezo_apply_messages(params, seeds, coefs)
    slow = params
    for s, c in zip(seeds, coefs):
        slow = zo.tree_add_scaled(slow, zo.mezo_z(params, s), c)
    for a, b in zip(jax.tree.leaves(fast), jax.tree.leaves(slow)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
