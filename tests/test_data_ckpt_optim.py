"""Data pipeline, checkpointing, optimizers, LoRA."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import synthetic
from repro.dtrain import lora as loralib
from repro.models import params as plib
from repro.models import transformer as tf
from repro.optim import sgd
from repro.dtrain.runner import sim_arch


def test_splits_deterministic_and_disjoint_sizes():
    task = synthetic.TaskConfig(n_train=128, n_valid=50, n_test=100)
    tr1, va1, te1 = synthetic.make_splits(task)
    tr2, _, _ = synthetic.make_splits(task)
    np.testing.assert_array_equal(tr1.tokens, tr2.tokens)
    assert len(tr1) == 128 and len(va1) == 50 and len(te1) == 100
    assert tr1.tokens.shape[1] == task.seq_len + 1


def test_classify_labels_are_class_tokens():
    task = synthetic.TaskConfig(n_train=64, vocab=256, n_classes=4)
    tr, _, _ = synthetic.make_splits(task)
    assert ((tr.labels >= 252) & (tr.labels < 256)).all()
    np.testing.assert_array_equal(tr.tokens[:, -1], tr.labels)


def test_partition_uniform_covers_everything():
    task = synthetic.TaskConfig(n_train=128)
    tr, _, _ = synthetic.make_splits(task)
    parts = synthetic.partition(tr, 8)
    allidx = np.concatenate(parts)
    assert len(allidx) == 128 and len(set(allidx.tolist())) == 128
    assert all(len(p) == 16 for p in parts)   # paper: even partition


def test_partition_dirichlet_skews():
    task = synthetic.TaskConfig(n_train=512)
    tr, _, _ = synthetic.make_splits(task)
    parts = synthetic.partition(tr, 4, scheme="dirichlet", dirichlet_alpha=0.1)
    assert sum(len(p) for p in parts) == 512
    sizes = sorted(len(p) for p in parts)
    assert sizes[-1] > sizes[0]               # alpha=0.1 is very skewed


def test_client_batch_stateless_reproducible():
    task = synthetic.TaskConfig(n_train=64)
    tr, _, _ = synthetic.make_splits(task)
    parts = synthetic.partition(tr, 4)
    b1 = synthetic.client_batch(tr, parts[2], 2, 7, 8)
    b2 = synthetic.client_batch(tr, parts[2], 2, 7, 8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic.client_batch(tr, parts[2], 2, 8, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_checkpoint_roundtrip(tmp_path):
    cfg = sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64)
    params = tf.init_params(cfg, seed=3)
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, params, {"step": 17})
    loaded, meta = ckpt.load(path, like=params)
    assert meta["step"] == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 3}
    path = os.path.join(tmp_path, "bf.npz")
    ckpt.save(path, tree)
    loaded, _ = ckpt.load(path, like=tree)
    assert loaded["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(loaded["w"], jnp.float32),
                                  np.asarray(tree["w"], jnp.float32))


def test_checkpoint_mismatch_rejected(tmp_path):
    path = os.path.join(tmp_path, "m.npz")
    ckpt.save(path, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.load(path, like={"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_sgd_and_adam_descend_quadratic():
    params = {"w": 3.0 * jnp.ones(8)}
    loss = lambda p: 0.5 * jnp.sum(p["w"] ** 2)
    g = jax.grad(loss)
    st = sgd.sgd_init(params, momentum=0.9)
    p = params
    for _ in range(50):
        p, st = sgd.sgd_update(p, g(p), st, lr=0.05, momentum=0.9)
    assert float(loss(p)) < 0.05 * float(loss(params))

    ast = sgd.adam_init(params)
    p = params
    for _ in range(100):
        p, ast = sgd.adam_update(p, g(p), ast, lr=0.1)
    assert float(loss(p)) < 0.05 * float(loss(params))


def test_lora_spec_and_merge():
    cfg = sim_arch(d_model=32, n_layers=2, n_heads=2, d_ff=64)
    spec = tf.arch_spec(cfg)
    lspec = loralib.lora_spec(spec, r=4)
    n_l = loralib.n_lora_params(lspec)
    assert 0 < n_l < 0.05 * plib.n_params(spec)
    params = plib.init_params(spec, 0)
    adapters = loralib.lora_init(lspec, 1)
    merged = loralib.merge(params, adapters, alpha=16.0)
    # B is zero-init => merge is identity initially
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    # make B nonzero -> wq changes, wk doesn't
    adapters = jax.tree.map(lambda x: x + 0.1, adapters)
    merged = loralib.merge(params, adapters, alpha=16.0)
    assert not np.allclose(np.asarray(merged["g0"]["s0"]["wq"]),
                           np.asarray(params["g0"]["s0"]["wq"]))
    np.testing.assert_array_equal(np.asarray(merged["g0"]["s0"]["wk"]),
                                  np.asarray(params["g0"]["s0"]["wk"]))
