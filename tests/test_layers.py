"""Layer-level correctness: mamba scans, MoE dispatch, attention masks."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.configs.base import MoECfg
from repro.models import layers as L
from repro.models.perturb import Bundle


# ---------------------------------------------------------------------------
# SSM scan
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(st.integers(1, 3), st.sampled_from([8, 12, 32]), st.integers(1, 4))
def test_chunked_scan_equals_sequential(B, T, chunk):
    key = jax.random.PRNGKey(T * 7 + B)
    ks = jax.random.split(key, 3)
    D, N = 6, 4
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D, N)))
    bx = 0.1 * jax.random.normal(ks[1], (B, T, D, N))
    h0 = jax.random.normal(ks[2], (B, D, N))

    h_all, h_last = L._ssm_chunked(a, bx, h0, chunk)

    h = h0
    seq = []
    for t in range(T):
        h = a[:, t] * h + bx[:, t]
        seq.append(h)
    want = jnp.stack(seq, axis=1)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_causal_conv_matches_numpy():
    B, T, D, K = 2, 10, 4, 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, T, D))
    w = jax.random.normal(jax.random.fold_in(key, 1), (D, K))
    b = jax.random.normal(jax.random.fold_in(key, 2), (D,))
    got = np.asarray(L._causal_conv(x, w, b))
    xn = np.asarray(x)
    wn = np.asarray(w)
    want = np.zeros((B, T, D))
    for t in range(T):
        for k in range(K):
            src = t - (K - 1) + k
            if src >= 0:
                want[:, t] += xn[:, src] * wn[:, k]
    want += np.asarray(b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# attention masks
# ---------------------------------------------------------------------------

def test_attn_mask_causal_and_window():
    q_pos = jnp.arange(6)
    k_pos = jnp.arange(6)
    m = np.asarray(L.attn_mask(q_pos, k_pos, window=None))
    assert m[3, 3] and m[3, 0] and not m[3, 4]
    mw = np.asarray(L.attn_mask(q_pos, k_pos, window=2))
    assert mw[3, 3] and mw[3, 2] and not mw[3, 1]


def test_attn_mask_ignores_unwritten_slots():
    q_pos = jnp.asarray([5])
    k_pos = jnp.asarray([3, 4, 5, -1, -1])
    m = np.asarray(L.attn_mask(q_pos, k_pos, None))[0]
    np.testing.assert_array_equal(m, [True, True, True, False, False])


def test_gqa_reduces_to_mha_when_kv_equals_heads():
    B, T, H, hd = 2, 8, 4, 16
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hd))
    pos = jnp.arange(T)
    out = L.attn_core(q, k, v, pos, pos, None)
    assert out.shape == (B, T, H * hd)
    # per-head manual check for head 0, query T-1 (full causal context)
    lg = np.asarray(jnp.einsum("bd,bsd->bs", q[:, -1, 0], k[:, :, 0])) / np.sqrt(hd)
    w = np.exp(lg - lg.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    want = np.einsum("bs,bsd->bd", w, np.asarray(v[:, :, 0]))
    np.testing.assert_allclose(np.asarray(out[:, -1, :hd]), want,
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

def _moe_bundle(key, E, D, F, gated=True):
    ks = jax.random.split(key, 5)
    p = {"router": 0.1 * jax.random.normal(ks[0], (D, E)),
         "w1": 0.1 * jax.random.normal(ks[1], (E, D, F)),
         "w3": 0.1 * jax.random.normal(ks[2], (E, D, F)),
         "w2": 0.1 * jax.random.normal(ks[3], (E, F, D))}
    return Bundle(p), ks[4]


def test_moe_matches_dense_reference_when_dropless():
    """Capacity dispatch == explicit per-token dense computation when
    capacity is large enough that nothing drops."""
    B, T, D, F, E, K = 2, 6, 8, 16, 4, 2
    b, key = _moe_bundle(jax.random.PRNGKey(1), E, D, F)
    x = jax.random.normal(key, (B, T, D))
    mcfg = MoECfg(n_experts=E, top_k=K, d_ff_expert=F, capacity_factor=8.0)
    got, aux = L.moe(b, x, mcfg, act="silu", gated=True)

    # dense reference
    xt = np.asarray(x).reshape(-1, D)
    logits = xt @ np.asarray(b.p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    want = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:K]
        pw = probs[t][top]
        pw = pw / pw.sum()
        for e, wgt in zip(top, pw):
            h = (xt[t] @ np.asarray(b.p["w1"][e]))
            h = h / (1 + np.exp(-h)) * (xt[t] @ np.asarray(b.p["w3"][e]))
            want[t] += wgt * (h @ np.asarray(b.p["w2"][e]))
    np.testing.assert_allclose(np.asarray(got).reshape(-1, D), want,
                               rtol=2e-3, atol=2e-3)
    assert float(aux) == 0.0     # router_aux = 0


def test_moe_capacity_drops_tokens_not_crash():
    B, T, D, F, E, K = 1, 32, 8, 16, 4, 2
    b, key = _moe_bundle(jax.random.PRNGKey(2), E, D, F)
    x = jax.random.normal(key, (B, T, D))
    mcfg = MoECfg(n_experts=E, top_k=K, d_ff_expert=F, capacity_factor=0.25)
    got, _ = L.moe(b, x, mcfg, act="silu", gated=True)
    assert got.shape == x.shape
    assert np.isfinite(np.asarray(got)).all()


def test_dispatch_indices_positions_are_dense_per_expert():
    idx = jnp.asarray([[0, 1], [0, 2], [0, 1], [3, 0]])
    pos, keep = L._dispatch_indices(idx, n_experts=4, capacity=3)
    pos = np.asarray(pos)
    # expert 0 receives tokens (0,s0),(1,s0),(2,s0),(3,s1): positions 0,1,2,3
    e0_pos = [pos[0, 0], pos[1, 0], pos[2, 0], pos[3, 1]]
    assert sorted(e0_pos) == [0, 1, 2, 3]
    assert not np.asarray(keep)[3, 1]     # 4th assignment exceeds capacity 3
