"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced same-family variant, runs a forward + one ZO train-ish step on CPU
with shape and NaN assertions; plus prefill+decode == full-forward
consistency for every family's cache machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import archs
from repro.configs.base import INPUT_SHAPES
from repro.core import subcge
from repro.core.subcge import SubCGEConfig
from repro.models import params as plib
from repro.models import transformer as tf
from repro.models.perturb import nest_subspace, sample_pert

SCFG = SubCGEConfig(rank=4, refresh_period=50)


def _setup(name):
    cfg = archs.reduced(archs.get(name))
    spec = tf.arch_spec(cfg)
    params = plib.init_params(spec, 0)
    return cfg, spec, params


def _batch(cfg, B=2, T=16, key=0):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, T),
                                          0, cfg.vocab)}
    if cfg.frontend is not None:
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(key + 1),
            (B, cfg.frontend.n_embeds, cfg.frontend.embed_dim))
    return batch


@pytest.mark.parametrize("name", archs.ASSIGNED)
def test_smoke_forward_shapes_no_nans(name):
    cfg, spec, params = _setup(name)
    B, T = 2, 16
    batch = _batch(cfg, B, T)
    logits, _, aux = tf.forward(cfg, params, batch)
    P = cfg.frontend.n_embeds if cfg.frontend else 0
    assert logits.shape == (B, T + P, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", archs.ASSIGNED)
def test_smoke_one_zo_train_step(name):
    """One full SeedFlood-style update: dual forward + SubCGE aggregation.
    Params must change, stay finite, and the loss must be finite."""
    cfg, spec, params = _setup(name)
    meta = plib.subcge_meta(spec)
    batch = _batch(cfg)
    sub_flat = subcge.subspace_at_step(meta, SCFG, 3, 0)
    sub = nest_subspace(sub_flat)

    seeds_t = jnp.asarray([101, 202], jnp.uint32)   # 2 clients
    alphas = []
    for s in seeds_t:
        pert = sample_pert(meta, SCFG, s, SCFG.eps)
        lp = tf.lm_loss(cfg, params, batch, sub=sub, pert=pert)
        lm = tf.lm_loss(cfg, params, batch, sub=sub,
                        pert=pert.with_scale(-SCFG.eps))
        assert np.isfinite(float(lp)) and np.isfinite(float(lm))
        alphas.append((lp - lm) / (2 * SCFG.eps))
    coefs = -1e-3 * jnp.asarray(alphas) / 2
    new = subcge.apply_messages(params, meta, SCFG, sub_flat, seeds_t, coefs)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)))
    assert changed
    for leaf in jax.tree.leaves(new):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("name", archs.ASSIGNED)
def test_prefill_decode_matches_full_forward(name):
    cfg, spec, params = _setup(name)
    B, T = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 1), 0, cfg.vocab)
    full = {"tokens": toks}
    P = 0
    if cfg.frontend is not None:
        emb = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.frontend.n_embeds,
                                 cfg.frontend.embed_dim))
        full["embeds"] = emb
        P = cfg.frontend.n_embeds
    ref, _, _ = tf.forward(cfg, params, full)

    cache = tf.init_cache(cfg, B, capacity=P + T + 1, dtype=jnp.float32)
    pre = {"tokens": toks[:, :T]}
    if P:
        pre["embeds"] = emb
    lg1, cache, _ = tf.forward(cfg, params, pre, cache=cache, pos=0)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(ref[:, :P + T]),
                               rtol=2e-4, atol=2e-4)
    lg2, cache, _ = tf.forward(cfg, params, {"tokens": toks[:, T:]},
                               cache=cache, pos=P + T)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(ref[:, -1]),
                               rtol=3e-4, atol=3e-4)


def test_sliding_window_variant_changes_only_windows():
    cfg = archs.get("qwen2-72b")
    sw = cfg.with_sliding_window(4096)
    assert sw.n_layers == cfg.n_layers
    for s in sw.layer_cfgs():
        assert s.attn.window == 4096
    # gemma3 keeps its tighter local windows
    g3 = archs.get("gemma3-1b").with_sliding_window(4096)
    wins = {s.attn.window for s in g3.layer_cfgs()}
    assert wins == {512, 4096}


def test_for_shape_applies_sliding_window_on_long_decode():
    long = INPUT_SHAPES["long_500k"]
    dense = archs.get("tinyllama-1.1b").for_shape(long)
    assert all(s.attn.window == 4096 for s in dense.layer_cfgs())
    native = archs.get("falcon-mamba-7b").for_shape(long)
    assert native.name == "falcon-mamba-7b"      # untouched


def test_perturbed_forward_scale_zero_is_identity():
    cfg, spec, params = _setup("tinyllama-1.1b")
    meta = plib.subcge_meta(spec)
    batch = _batch(cfg)
    sub = nest_subspace(subcge.subspace_at_step(meta, SCFG, 0, 0))
    pert = sample_pert(meta, SCFG, jnp.uint32(9), 0.0)
    a, _, _ = tf.forward(cfg, params, batch)
    b, _, _ = tf.forward(cfg, params, batch, sub=sub, pert=pert)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_fused_rank1_equals_materialized_perturbation():
    """The fused x·W + s(x·u)v^T path must equal forwarding through
    explicitly perturbed weights (materialize_z) — the core correctness of
    the production forward."""
    cfg, spec, params = _setup("qwen1.5-0.5b")
    meta = plib.subcge_meta(spec)
    batch = _batch(cfg)
    sub_flat = subcge.subspace_at_step(meta, SCFG, 1, 0)
    eps = 1e-2
    pert = sample_pert(meta, SCFG, jnp.uint32(77), eps)
    fused = tf.lm_loss(cfg, params, batch, sub=nest_subspace(sub_flat),
                       pert=pert)
    z = subcge.materialize_z(params, meta, SCFG, sub_flat, jnp.uint32(77))
    pmat = jax.tree.map(lambda p, zz: p + eps * zz.astype(p.dtype), params, z)
    mat = tf.lm_loss(cfg, pmat, batch)
    np.testing.assert_allclose(float(fused), float(mat), rtol=2e-4)
