import os
import sys

# tests run on the single host CPU device (the 512-device forcing lives ONLY
# in repro.launch.dryrun, which is exercised via subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
