import os
import sys

# tests run on the single host CPU device (the 512-device forcing lives ONLY
# in repro.launch.dryrun, which is exercised via subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    # XLA-CPU state accumulated over hundreds of jit compilations in one
    # process eventually segfaults inside backend_compile (seen at ~400
    # tests); dropping compiled executables at module boundaries keeps the
    # process healthy at the cost of some cross-module recompilation.
    yield
    jax.clear_caches()
