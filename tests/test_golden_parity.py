"""Golden parity: the Method × Transport plugin API reproduces the
pre-refactor monolith runner BITWISE.

``tests/golden_monolith.py`` is a frozen verbatim copy of the monolith's
four training loops; every registry method runs through both and must
produce identical loss curves, byte ledgers, consensus errors and final
parameters.  ZO training amplifies float32 round-off ~500× per step, so
anything short of bitwise equality here would hide a real behavioral
change — the two implementations share every jitted computation, and XLA
CPU is deterministic for identical programs.

Marked ``golden`` (runs in tier-1; deselect with -m "not golden").
"""
import jax
import numpy as np
import pytest

import golden_monolith
from repro.dtrain.runner import DTrainConfig, METHODS, run, sim_arch
from repro.topology.dynamic import ChurnSchedule

pytestmark = pytest.mark.golden

ALL_METHODS = sorted(METHODS)


def _cfg(**kw):
    base = dict(n_clients=4, topology="ring", steps=3, lr=1e-2, batch_size=4,
                subcge_rank=8, local_iters=2,   # gossip rounds fire in-test
                arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))
    base.update(kw)
    return DTrainConfig(**base)


def _assert_bitwise(old, new):
    # acc_curve is compared only via test_eval_cadence_matches (seedflood):
    # the monolith ignored eval_every for gossip_sr/central_zo, and the
    # Trainer deliberately honors it uniformly (test_trainer_api pins that).
    assert old.loss_curve == new.loss_curve
    assert old.total_bytes == new.total_bytes
    assert old.bytes_per_edge == new.bytes_per_edge
    assert old.consensus_error == new.consensus_error
    assert old.gmp == new.gmp
    assert old.method == new.method
    for key in ("final_stacked", "final_params"):
        if key in old.extra:
            assert key in new.extra
            for a, b in zip(jax.tree.leaves(old.extra[key]),
                            jax.tree.leaves(new.extra[key])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("method", ALL_METHODS)
def test_method_matches_monolith(method):
    cfg = _cfg(method=method)
    _assert_bitwise(golden_monolith.run(cfg), run(cfg))


def test_registry_covers_every_monolith_method():
    assert set(METHODS) == set(golden_monolith.METHODS)


def test_seedflood_per_client_reference_path():
    cfg = _cfg(method="seedflood", batched_step=False)
    _assert_bitwise(golden_monolith.run(cfg), run(cfg))


def test_seedflood_churn_path():
    """Leave + rejoin (anti-entropy catch-up, effective-diameter tracking,
    offline freeze) through the Trainer == through the monolith."""
    churn = ChurnSchedule.leave_rejoin([2], leave_at=1, rejoin_at=3)
    cfg = _cfg(method="seedflood", steps=5, churn=churn, subcge_tau=2)
    _assert_bitwise(golden_monolith.run(cfg), run(cfg))


def test_gossip_churn_path():
    churn = ChurnSchedule.leave_rejoin([1], leave_at=1, rejoin_at=3)
    cfg = _cfg(method="dzsgd", steps=4, churn=churn)
    _assert_bitwise(golden_monolith.run(cfg), run(cfg))


def test_seedflood_drain_and_delayed_flooding_path():
    """k=1 delayed flooding with τ below the staleness bound, plus the
    end-of-run drain — the cross-epoch replay machinery end to end."""
    cfg = _cfg(method="seedflood", n_clients=6, steps=4, flood_k=1,
               subcge_tau=2, drain=True)
    _assert_bitwise(golden_monolith.run(cfg), run(cfg))


def test_eval_cadence_matches():
    cfg = _cfg(method="seedflood", steps=4, eval_every=2)
    old, new = golden_monolith.run(cfg), run(cfg)
    assert old.acc_curve == new.acc_curve
    assert old.extra["consensus_curve"] == new.extra["consensus_curve"]
