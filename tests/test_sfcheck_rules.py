"""Per-rule fixtures for sfcheck (`repro.analysis`): every SF0xx rule
has at least one minimal violating snippet (the rule must fire) and one
clean snippet (the rule must stay quiet), plus suppression-comment
semantics (SF000 justification hygiene).

These run on in-memory Projects — no filesystem, no jit, fast."""
from repro.analysis.engine import (PARSE_ERROR_CODE, SUPPRESSION_CODE,
                                   Project, run_rules)


def diags(sources, rel="src/repro/core/mod.py", select=None):
    if isinstance(sources, str):
        sources = {rel: sources}
    return run_rules(Project.from_sources(sources), select=select)


def codes(sources, rel="src/repro/core/mod.py", select=None):
    return sorted({d.code for d in diags(sources, rel, select)})


# ---------------------------------------------------------------------------
# SF001 seed hygiene
# ---------------------------------------------------------------------------

def test_sf001_unseeded_default_rng_fires():
    assert codes("import numpy as np\nrng = np.random.default_rng()\n") \
        == ["SF001"]


def test_sf001_global_numpy_rng_fires():
    assert codes("import numpy as np\nnp.random.seed(0)\n") == ["SF001"]
    assert codes("import numpy as np\nx = np.random.rand(3)\n") == ["SF001"]


def test_sf001_stdlib_random_fires():
    assert codes("import random\nx = random.random()\n") == ["SF001"]
    assert codes("import random\nrandom.shuffle([1, 2])\n") == ["SF001"]


def test_sf001_clock_derived_seed_fires():
    src = ("import time\nimport numpy as np\n"
           "rng = np.random.default_rng(int(time.time()))\n")
    assert codes(src) == ["SF001"]
    assert codes("import time\nbase_seed = int(time.time())\n") == ["SF001"]
    src = ("import time\n"
           "def f(run):\n    run(seed=int(time.time_ns()))\n")
    assert codes(src) == ["SF001"]


def test_sf001_seeded_rng_is_clean():
    src = ("import numpy as np\n"
           "rng = np.random.default_rng(42)\n"
           "x = rng.normal(size=3)\n"
           "y = rng.integers(0, 10)\n")
    assert codes(src) == []


def test_sf001_jax_counter_rng_is_clean():
    src = ("import jax\n"
           "def f(seed, step):\n"
           "    return jax.random.fold_in(jax.random.PRNGKey(seed), step)\n")
    assert codes(src) == []


def test_sf001_wallclock_logging_is_clean():
    # wall-clock *logging* derives no seed — never flagged, anywhere
    src = "import time\nt0 = time.time()\nwall = time.time() - t0\n"
    assert codes(src) == []


def test_sf001_launch_and_benchmarks_may_clock_label():
    src = ("import time\nimport numpy as np\n"
           "rng = np.random.default_rng(int(time.time()))\n")
    assert codes(src, rel="src/repro/launch/sweep.py") == []
    assert codes(src, rel="benchmarks/bench_x.py") == []
    # ...but global RNG state stays banned even there
    bad = "import numpy as np\nnp.random.seed(0)\n"
    assert codes(bad, rel="src/repro/launch/sweep.py") == ["SF001"]


# ---------------------------------------------------------------------------
# SF002 trace safety
# ---------------------------------------------------------------------------

def test_sf002_clock_in_jit_fires():
    src = ("import jax\nimport time\n"
           "@jax.jit\ndef f(x):\n    return x + time.time()\n")
    assert codes(src) == ["SF002"]


def test_sf002_print_and_item_in_jit_fire():
    src = ("import jax\n"
           "@jax.jit\ndef f(x):\n    print(x)\n    return x\n")
    assert codes(src) == ["SF002"]
    src = ("import jax\n"
           "@jax.jit\ndef f(x):\n    return float(x.sum().item())\n")
    assert codes(src) == ["SF002"]


def test_sf002_partial_jit_decorator_and_jit_call_fire():
    src = ("import functools\nimport jax\nimport time\n"
           "@functools.partial(jax.jit, donate_argnums=(0,))\n"
           "def f(x):\n    return x * time.time()\n")
    assert codes(src) == ["SF002"]
    src = ("import jax\nimport time\n"
           "def f(x):\n    return x * time.time()\n"
           "g = jax.jit(f, static_argnums=())\n")
    assert codes(src) == ["SF002"]


def test_sf002_mutable_global_capture_fires():
    src = ("import jax\n"
           '_backend = "auto"\n'
           "def set_backend(b):\n"
           "    global _backend\n"
           "    _backend = b\n"
           "@jax.jit\ndef f(x):\n"
           '    return x if _backend == "jnp" else -x\n')
    assert codes(src) == ["SF002"]


def test_sf002_global_statement_in_jit_fires():
    src = ("import jax\n_n = 0\n_n = 1\n"
           "@jax.jit\ndef f(x):\n    global _n\n    _n = 2\n    return x\n")
    assert "SF002" in codes(src)


def test_sf002_host_loop_clock_is_clean():
    src = ("import jax\nimport time\n"
           "@jax.jit\ndef step(x):\n    return x + 1\n"
           "def run(x):\n    t0 = time.time()\n"
           "    x = step(x)\n    return x, time.time() - t0\n")
    assert codes(src) == []


def test_sf002_module_constant_read_is_clean():
    # single-assignment module dict is a constant table, not mutable state
    src = ("import jax\n"
           'ACTS = {"a": 1}\n'
           "@jax.jit\ndef f(x):\n"
           '    return x + ACTS["a"]\n')
    assert codes(src) == []


def test_sf002_shadowing_param_is_clean():
    src = ("import jax\n_cfg = 1\n_cfg = 2\n"
           "@jax.jit\ndef f(_cfg):\n    return _cfg + 1\n")
    assert codes(src) == []


# ---------------------------------------------------------------------------
# SF003 iteration order
# ---------------------------------------------------------------------------

def test_sf003_for_over_set_fires():
    src = "s = {1, 2, 3}\nacc = 0.0\nfor x in s:\n    acc += x\n"
    assert codes(src) == ["SF003"]


def test_sf003_set_difference_and_union_fire():
    src = ("def f(a, b):\n"
           "    out = []\n"
           "    for x in set(a) - set(b):\n"
           "        out.append(x)\n"
           "    return out\n")
    assert codes(src) == ["SF003"]
    src = "u = set()\nu |= {1}\ntotal = sum(u)\n"
    assert codes(src) == ["SF003"]


def test_sf003_comprehension_and_list_over_set_fire():
    assert codes("d = {k: 0 for k in {1, 2}}\n") == ["SF003"]
    assert codes("xs = list({1, 2})\n") == ["SF003"]


def test_sf003_module_set_iterated_in_function_fires():
    src = ("NAMES = set()\n"
           "def f():\n    return [n for n in NAMES]\n")
    assert codes(src) == ["SF003"]


def test_sf003_filesystem_listing_fires():
    src = ("import glob\n"
           "def f():\n"
           "    return [open(p) for p in glob.glob('*.json')]\n")
    assert codes(src) == ["SF003"]
    src = ("import os\n"
           "def f(d):\n"
           "    for name in os.listdir(d):\n        print(name)\n")
    assert codes(src) == ["SF003"]


def test_sf003_sorted_blesses_everything():
    src = ("import glob\n"
           "s = {3, 1}\n"
           "xs = [x for x in sorted(s)]\n"
           "fs = sorted(glob.glob('*.json'))\n"
           "for f in fs:\n    print(f)\n")
    assert codes(src) == []


def test_sf003_order_insensitive_uses_are_clean():
    src = ("s = {1, 2}\nt = {2, 3}\n"
           "n = len(s)\nok = 1 in s\nm = max(s)\n"
           "u = s | t\nboth = s & t\n"
           "mapped = {x + 1 for x in s}\n")
    assert codes(src) == []


# ---------------------------------------------------------------------------
# SF004 config-field consumption
# ---------------------------------------------------------------------------

_CFG = ("import dataclasses\n"
        "@dataclasses.dataclass\n"
        "class DTrainConfig:\n"
        "    lr: float = 0.1\n"
        "    dead_knob: int = 0\n")


def test_sf004_unread_field_fires():
    ds = diags({"src/repro/dtrain/runner.py": _CFG,
                "src/repro/dtrain/trainer.py": "def f(cfg):\n    return cfg.lr\n"})
    assert [d.code for d in ds] == ["SF004"]
    assert "dead_knob" in ds[0].message


def test_sf004_attribute_read_consumes():
    ds = diags({"src/repro/dtrain/runner.py": _CFG,
                "src/repro/dtrain/trainer.py":
                    "def f(cfg):\n    return cfg.lr * cfg.dead_knob\n"})
    assert ds == []


def test_sf004_rejection_table_string_consumes():
    src = _CFG + '_METHOD_FIELDS = ("dead_knob",)\n'
    ds = diags({"src/repro/dtrain/runner.py": src,
                "src/repro/dtrain/trainer.py": "def f(cfg):\n    return cfg.lr\n"})
    assert ds == []


def test_sf004_docstring_mention_does_not_consume():
    ds = diags({"src/repro/dtrain/runner.py": _CFG,
                "src/repro/dtrain/trainer.py":
                    '"""the dead_knob knob is cool"""\n'
                    "def f(cfg):\n    return cfg.lr\n"})
    assert [d.code for d in ds] == ["SF004"]


def test_sf004_ignores_config_classes_outside_src():
    ds = diags({"tests/helper.py": _CFG})
    assert ds == []


# ---------------------------------------------------------------------------
# SF005 ledger conservation
# ---------------------------------------------------------------------------

_TRANSPORT = ("class TransportBase:\n"
              "    ledger = None\n"
              "class FloodTransport(TransportBase):\n"
              "    def exchange(self, net, payload, t):\n"
              "        net.inject(0, payload)\n"
              "        return net.rounds_padded(2)\n")


def test_sf005_transport_enqueue_is_clean():
    assert diags({"src/repro/core/transport.py": _TRANSPORT}) == []


def test_sf005_enqueue_outside_transport_fires():
    ds = diags({"src/repro/core/transport.py": _TRANSPORT,
                "src/repro/dtrain/trainer.py":
                    "def run(net, msg):\n    net.inject(0, msg)\n"})
    assert [d.code for d in ds] == ["SF005"]
    ds = diags({"src/repro/core/transport.py": _TRANSPORT,
                "src/repro/dtrain/methods/sneaky.py":
                    "class SneakyMethod:\n"
                    "    def step(self, net):\n"
                    "        return net.rounds_arrays(1)\n"})
    assert [d.code for d in ds] == ["SF005"]


def test_sf005_gossip_module_functions_fire():
    ds = diags({"src/repro/core/transport.py": _TRANSPORT,
                "src/repro/dtrain/methods/g.py":
                    "from repro.core import gossip\n"
                    "def f(x, W):\n    return gossip.mix(x, W)\n"})
    assert [d.code for d in ds] == ["SF005"]


def test_sf005_serve_scope_fires_and_transport_calls_stay_clean():
    # the serving swarm rides the flood: a server injecting directly would
    # receive updates no ledger billed — serve/ is in scope
    ds = diags({"src/repro/core/transport.py": _TRANSPORT,
                "src/repro/serve/sneaky_sim.py":
                    "def tick(net, msg):\n    net.inject(0, msg)\n"})
    assert [d.code for d in ds] == ["SF005"]
    # calling Transport *methods* (exchange / apply_churn) is the sanctioned
    # path — those charge the CommLedger themselves
    ds = diags({"src/repro/core/transport.py": _TRANSPORT,
                "src/repro/serve/sim.py":
                    "class ServeSwarmSim:\n"
                    "    def tick(self, transport, msgs, t, active):\n"
                    "        return transport.exchange(msgs, t, active)\n"})
    assert ds == []


def test_sf005_substrate_and_tests_are_out_of_scope():
    # flood.py implements the primitives; tests drive networks directly
    ds = diags({"src/repro/core/flood.py":
                    "class FloodNetwork:\n"
                    "    def full_flood(self):\n"
                    "        return self.rounds(3)\n",
                "tests/test_x.py": "def t(net, m):\n    net.inject(0, m)\n"})
    assert ds == []


# ---------------------------------------------------------------------------
# SF006 kernel dispatch
# ---------------------------------------------------------------------------

def test_sf006_ref_import_outside_kernels_fires():
    ds = diags("from repro.kernels import ref\n",
               rel="src/repro/models/perturb.py")
    assert [d.code for d in ds] == ["SF006"]


def test_sf006_pallas_call_outside_kernels_fires():
    src = ("import jax.experimental.pallas as pl\n"
           "out = pl.pallas_call(None)\n")
    ds = diags(src, rel="src/repro/core/subcge.py")
    assert [d.code for d in ds] == ["SF006", "SF006"]  # import + call


def test_sf006_package_attribute_path_fires():
    ds = diags("from repro import kernels\ny = kernels.ref.subcge_apply\n")
    assert [d.code for d in ds] == ["SF006"]


def test_sf006_ops_dispatch_is_clean():
    src = ("from repro.kernels import ops as kops\n"
           "def f(W, U, A, V):\n"
           "    return kops.subcge_apply(W, U, A, V, backend='jnp')\n")
    assert diags(src) == []


def test_sf006_inside_kernels_is_clean():
    src = ("import jax.experimental.pallas as pl\n"
           "from repro.kernels import ref\n"
           "out = pl.pallas_call(None)\n")
    assert diags(src, rel="src/repro/kernels/new_kernel.py") == []


# ---------------------------------------------------------------------------
# SF002 interprocedural (the whole-program traced set)
# ---------------------------------------------------------------------------

def test_sf002_transitive_backend_sniffing_fires():
    # the PR 4 bug class: the jit decorator sits in one module, the
    # mutable-global read hides in a helper module — only the project
    # call graph connects them
    sources = {
        "src/repro/core/hot.py": (
            "import jax\n"
            "from repro.core.backends import resolve\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    return resolve(x)\n"),
        "src/repro/core/backends.py": (
            "_default_backend = 'auto'\n"
            "def set_backend(b):\n"
            "    global _default_backend\n"
            "    _default_backend = b\n"
            "def resolve(x):\n"
            "    if _default_backend == 'neg':\n"
            "        return -x\n"
            "    return x\n"),
    }
    ds = diags(sources)
    assert [(d.code, d.path) for d in ds] \
        == [("SF002", "src/repro/core/backends.py")]


def test_sf002_transitive_through_two_hops_fires():
    sources = {
        "src/repro/core/a.py": (
            "import jax\n"
            "from repro.core.b import mid\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    return mid(x)\n"),
        "src/repro/core/b.py": (
            "from repro.core.c import leaf\n"
            "def mid(x):\n"
            "    return leaf(x)\n"),
        "src/repro/core/c.py": (
            "import time\n"
            "def leaf(x):\n"
            "    return x * time.time()\n"),
    }
    ds = diags(sources)
    assert [(d.code, d.path) for d in ds] \
        == [("SF002", "src/repro/core/c.py")]


def test_sf002_untraced_helper_is_clean():
    # same helper, but nothing jit-traces the caller: no finding
    sources = {
        "src/repro/core/a.py": (
            "from repro.core.b import mid\n"
            "def f(x):\n"
            "    return mid(x)\n"),
        "src/repro/core/b.py": (
            "import time\n"
            "def mid(x):\n"
            "    return x * time.time()\n"),
    }
    assert diags(sources) == []


# ---------------------------------------------------------------------------
# SF007 retrace hazards
# ---------------------------------------------------------------------------

def test_sf007_jit_in_loop_fires():
    # the PR 9 bug: a fresh jit wrapper per decode step recompiles the
    # forward pass per token
    src = ("import jax\n"
           "def serve(fn, toks):\n"
           "    out = []\n"
           "    for t in toks:\n"
           "        step = jax.jit(fn)\n"
           "        out.append(step(t))\n"
           "    return out\n")
    assert codes(src) == ["SF007"]


def test_sf007_immediately_invoked_jit_fires():
    src = ("import jax\n"
           "def f(x):\n"
           "    return x\n"
           "def g(x):\n"
           "    return jax.jit(f)(x)\n")
    assert codes(src) == ["SF007"]


def test_sf007_hoisted_jit_is_clean():
    src = ("import jax\n"
           "def serve(fn, toks):\n"
           "    step = jax.jit(fn)\n"
           "    return [step(t) for t in toks]\n")
    assert diags(src) == []


def test_sf007_keyed_cache_in_loop_is_clean():
    # the sanctioned idiom: programs stored under a shape key
    src = ("import jax\n"
           "def serve(fn, work, fns):\n"
           "    for t, key in work:\n"
           "        f = fns.get(key)\n"
           "        if f is None:\n"
           "            f = jax.jit(fn)\n"
           "            fns[key] = f\n"
           "        f(t)\n")
    assert diags(src) == []


def test_sf007_loop_var_in_jit_args_is_clean():
    # per-K programs in a benchmark sweep are per-K on purpose
    src = ("import jax\n"
           "def sweep(make, ks):\n"
           "    for K in ks:\n"
           "        f = jax.jit(make(K))\n"
           "        f()\n")
    assert diags(src) == []


def test_sf007_callee_rebuilt_in_loop_is_clean():
    src = ("import jax\n"
           "def sweep(modes, x):\n"
           "    for mode in modes:\n"
           "        def fn(v):\n"
           "            return v\n"
           "        j = jax.jit(fn)\n"
           "        j(x)\n")
    assert diags(src) == []


def test_sf007_factory_called_in_loop_fires():
    # the interprocedural PR 9 shape: the jit construction hides in a
    # factory; the loop call site is where the recompiles happen
    src = ("import jax\n"
           "def make_step(f):\n"
           "    return jax.jit(f)\n"
           "def run(f, xs):\n"
           "    for x in xs:\n"
           "        s = make_step(f)\n"
           "        s(x)\n")
    assert codes(src) == ["SF007"]


def test_sf007_factory_with_loop_var_arg_is_clean():
    src = ("import jax\n"
           "def make_step(k):\n"
           "    return jax.jit(lambda x: x * k)\n"
           "def run(ks, x):\n"
           "    for k in ks:\n"
           "        make_step(k)(x)\n")
    assert diags(src) == []


def test_sf007_factory_called_once_is_clean():
    src = ("import jax\n"
           "def make_step(f):\n"
           "    return jax.jit(f)\n"
           "def run(f, xs):\n"
           "    s = make_step(f)\n"
           "    return [s(x) for x in xs]\n")
    assert diags(src) == []


def test_sf007_jit_lambda_over_rebound_global_fires():
    src = ("import jax\n"
           "_mode = 'a'\n"
           "def set_mode(m):\n"
           "    global _mode\n"
           "    _mode = m\n"
           "j = jax.jit(lambda x: x if _mode == 'a' else -x)\n")
    assert codes(src) == ["SF007"]


# ---------------------------------------------------------------------------
# SF008 donation safety
# ---------------------------------------------------------------------------

_DONATING = ("import functools\n"
             "import jax\n"
             "@functools.partial(jax.jit, donate_argnums=(0,))\n"
             "def upd(p, g):\n"
             "    return p\n")


def test_sf008_use_after_donate_fires():
    src = _DONATING + ("def step(p, g):\n"
                       "    q = upd(p, g)\n"
                       "    return p + q\n")
    assert codes(src) == ["SF008"]


def test_sf008_rebind_is_clean():
    src = _DONATING + ("def step(p, g):\n"
                       "    p = upd(p, g)\n"
                       "    return p\n")
    assert diags(src) == []


def test_sf008_branch_return_is_clean():
    # the seedflood shape: the donating call returns out of the branch,
    # so the fall-through read is on a different path
    src = _DONATING + ("def step(p, g, fused):\n"
                       "    if fused:\n"
                       "        return upd(p, g)\n"
                       "    return p * 2\n")
    assert diags(src) == []


def test_sf008_loop_carried_donation_fires():
    # donated in iteration i, passed in again in iteration i+1
    src = _DONATING + ("def run(p, gs):\n"
                       "    for g in gs:\n"
                       "        upd(p, g)\n")
    assert codes(src) == ["SF008"]


def test_sf008_loop_rebind_is_clean():
    src = _DONATING + ("def run(p, gs):\n"
                       "    for g in gs:\n"
                       "        p = upd(p, g)\n"
                       "    return p\n")
    assert diags(src) == []


def test_sf008_donate_through_callee_fires():
    # interprocedural: middle() forwards its param into the donated
    # position, so outer's buffer dies at the middle() call
    src = _DONATING + ("def middle(buf, g):\n"
                       "    return upd(buf, g)\n"
                       "def outer(p, g):\n"
                       "    middle(p, g)\n"
                       "    return p.sum()\n")
    assert codes(src) == ["SF008"]


def test_sf008_wrap_form_donation_fires():
    src = ("import jax\n"
           "def f(p, g):\n"
           "    return p\n"
           "upd = jax.jit(f, donate_argnums=(0,))\n"
           "def step(p, g):\n"
           "    q = upd(p, g)\n"
           "    return p - q\n")
    assert codes(src) == ["SF008"]


def test_sf008_non_donating_call_is_clean():
    src = ("import jax\n"
           "@jax.jit\n"
           "def upd(p, g):\n"
           "    return p\n"
           "def step(p, g):\n"
           "    q = upd(p, g)\n"
           "    return p + q\n")
    assert diags(src) == []


# ---------------------------------------------------------------------------
# SF009 jit-cache-key completeness
# ---------------------------------------------------------------------------

_SERVE = "src/repro/serve/server.py"


def test_sf009_key_missing_factory_param_fires():
    src = ("import jax\n"
           "class Srv:\n"
           "    def __init__(self):\n"
           "        self._fns = {}\n"
           "    def _fn(self, Bg, T):\n"
           "        fn = self._fns.get((Bg,))\n"
           "        if fn is None:\n"
           "            def prefill(x):\n"
           "                return x\n"
           "            fn = jax.jit(prefill)\n"
           "            self._fns[(Bg,)] = fn\n"
           "        return fn\n")
    ds = diags(src, rel=_SERVE)
    assert [d.code for d in ds] == ["SF009"]
    assert "'T'" in ds[0].message


def test_sf009_complete_key_is_clean():
    src = ("import jax\n"
           "class Srv:\n"
           "    def __init__(self):\n"
           "        self._fns = {}\n"
           "    def _fn(self, Bg, T):\n"
           "        fn = self._fns.get((Bg, T))\n"
           "        if fn is None:\n"
           "            def prefill(x):\n"
           "                return x\n"
           "            fn = jax.jit(prefill)\n"
           "            self._fns[(Bg, T)] = fn\n"
           "        return fn\n")
    assert diags(src, rel=_SERVE) == []


def test_sf009_mutable_attr_in_closure_fires():
    # a cache hit replays a program compiled against the OLD self.scale
    src = ("import jax\n"
           "class Srv:\n"
           "    def __init__(self, scale):\n"
           "        self._fns = {}\n"
           "        self.scale = scale\n"
           "    def bump(self):\n"
           "        self.scale = self.scale + 1\n"
           "    def _fn(self, T):\n"
           "        fn = self._fns.get((T,))\n"
           "        if fn is None:\n"
           "            def f(x):\n"
           "                return x * self.scale\n"
           "            fn = jax.jit(f)\n"
           "            self._fns[(T,)] = fn\n"
           "        return fn\n")
    ds = diags(src, rel=_SERVE)
    assert [d.code for d in ds] == ["SF009"]
    assert "self.scale" in ds[0].message


def test_sf009_init_constant_attr_is_clean():
    src = ("import jax\n"
           "class Srv:\n"
           "    def __init__(self, meta):\n"
           "        self._fns = {}\n"
           "        self.meta = meta\n"
           "    def _fn(self, T):\n"
           "        fn = self._fns.get((T,))\n"
           "        if fn is None:\n"
           "            def f(x):\n"
           "                return x * self.meta\n"
           "            fn = jax.jit(f)\n"
           "            self._fns[(T,)] = fn\n"
           "        return fn\n")
    assert diags(src, rel=_SERVE) == []


def test_sf009_out_of_scope_is_silent():
    src = ("import jax\n"
           "class Srv:\n"
           "    def __init__(self):\n"
           "        self._fns = {}\n"
           "    def _fn(self, Bg, T):\n"
           "        fn = jax.jit(lambda x: x)\n"
           "        self._fns[(Bg,)] = fn\n"
           "        return fn\n")
    assert diags(src) == []          # default rel is core/: not a cache dir


# ---------------------------------------------------------------------------
# SF010 sender-step epoch flow
# ---------------------------------------------------------------------------

_DTRAIN = "src/repro/dtrain/methods/newmethod.py"


def test_sf010_receiver_step_substitution_fires():
    # the PR 2 bug, verbatim shape: payload steps overwritten with the
    # receiver's current step before the epoch computation
    src = ("import numpy as np\n"
           "from repro.core import flood, subcge\n"
           "def apply_inbox(inbox, scfg, t):\n"
           "    stp = np.where(inbox.coefs != 0.0, np.int32(t),\n"
           "                   np.int32(flood.STEP_PAD))\n"
           "    return subcge.epoch_slots(stp, scfg)\n")
    ds = diags(src, rel=_DTRAIN)
    assert [d.code for d in ds] == ["SF010"]
    assert "'t'" in ds[0].message


def test_sf010_payload_steps_passthrough_is_clean():
    src = ("from repro.core import subcge\n"
           "def apply_inbox(inbox, scfg):\n"
           "    return subcge.epoch_slots(inbox.steps, scfg)\n")
    assert diags(src, rel=_DTRAIN) == []


def test_sf010_padded_steps_buffer_is_clean():
    # the gossip_sr/bridge shape: a PAD-filled buffer whose live slots
    # carry the payload's sender steps
    src = ("import numpy as np\n"
           "from repro.core import flood, subcge\n"
           "def fold(sts, n, K, scfg):\n"
           "    pad_t = np.full(K, flood.STEP_PAD, np.int32)\n"
           "    pad_t[:n] = sts\n"
           "    return subcge.epoch_slots(pad_t, scfg)\n")
    assert diags(src, rel=_DTRAIN) == []


def test_sf010_no_step_origin_fires():
    src = ("import numpy as np\n"
           "from repro.core import subcge\n"
           "def apply_inbox(inbox, scfg, t):\n"
           "    return subcge.epoch_slots(np.int32(t), scfg)\n")
    ds = diags(src, rel=_DTRAIN)
    assert [d.code for d in ds] == ["SF010"]
    assert "no step-data origin" in ds[0].message


def test_sf010_dropped_payload_steps_fires():
    src = ("def ingest(inbox):\n"
           "    s = inbox.seeds\n"
           "    c = inbox.coefs\n"
           "    return s, c\n")
    ds = diags(src, rel=_DTRAIN)
    assert [d.code for d in ds] == ["SF010"]
    assert ".steps" in ds[0].message


def test_sf010_consumed_payload_steps_is_clean():
    src = ("def ingest(inbox):\n"
           "    return inbox.seeds, inbox.coefs, inbox.steps\n")
    assert diags(src, rel=_DTRAIN) == []


def test_sf010_epochless_replay_with_steps_in_hand_fires():
    src = ("from repro.core import subcge\n"
           "def replay(p, meta, cfg, sub, inbox):\n"
           "    sds = inbox.seeds\n"
           "    cfs = inbox.coefs\n"
           "    stp = inbox.steps\n"
           "    return subcge.apply_messages(p, meta, cfg, sub, sds, cfs)\n")
    ds = diags(src, rel=_DTRAIN)
    assert [d.code for d in ds] == ["SF010"]
    assert "apply_messages_epoch" in ds[0].message


def test_sf010_epoch_aware_replay_is_clean():
    src = ("from repro.core import subcge\n"
           "def replay(p, meta, cfg, seed, inbox, epochs):\n"
           "    return subcge.apply_messages_epoch(\n"
           "        p, meta, cfg, seed, inbox.seeds, inbox.coefs,\n"
           "        inbox.steps, epochs)\n")
    assert diags(src, rel=_DTRAIN) == []


def test_sf010_out_of_scope_is_silent():
    # core/ itself defines the substitution-free primitives; the rule
    # polices the *consumers* in dtrain//sim//serve
    src = ("import numpy as np\n"
           "from repro.core import flood, subcge\n"
           "def apply_inbox(inbox, scfg, t):\n"
           "    stp = np.where(inbox.coefs != 0.0, np.int32(t),\n"
           "                   np.int32(flood.STEP_PAD))\n"
           "    return subcge.epoch_slots(stp, scfg)\n")
    assert diags(src) == []


# ---------------------------------------------------------------------------
# SF000 suppressions
# ---------------------------------------------------------------------------

def test_justified_suppression_silences_the_rule():
    src = ("s = {1, 2}\n"
           "xs = list(s)  # sfcheck: noqa[SF003] -- membership snapshot, "
           "order never read\n")
    assert diags(src) == []


def test_unjustified_suppression_is_sf000():
    src = "s = {1, 2}\nxs = list(s)  # sfcheck: noqa[SF003]\n"
    assert codes(src) == [SUPPRESSION_CODE]


def test_blanket_suppression_with_reason():
    src = ("import numpy as np\n"
           "np.random.seed(0)  # sfcheck: noqa -- fixture corpus, "
           "not protocol randomness\n")
    assert diags(src) == []


def test_suppression_naming_unknown_rule_is_sf000():
    src = "x = 1  # sfcheck: noqa[SF777] -- no such rule\n"
    assert codes(src) == [SUPPRESSION_CODE]


def test_suppression_only_covers_named_codes():
    src = ("import numpy as np\n"
           "s = {1, 2}\n"
           "xs = [np.random.rand() for _ in s]"
           "  # sfcheck: noqa[SF003] -- order-free fixture\n")
    assert codes(src) == ["SF001"]


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def test_syntax_error_is_reported_not_raised():
    assert codes("def f(:\n") == [PARSE_ERROR_CODE]


def test_select_filters_rules():
    src = ("import numpy as np\nnp.random.seed(0)\n"
           "for x in {1, 2}:\n    print(x)\n")
    assert codes(src) == ["SF001", "SF003"]
    assert codes(src, select={"SF001"}) == ["SF001"]


def test_rule_catalogue_is_complete():
    from repro.analysis.rules import RULES
    assert [r.code for r in RULES] == [
        "SF001", "SF002", "SF003", "SF004", "SF005", "SF006",
        "SF007", "SF008", "SF009", "SF010"]
    assert all(r.summary for r in RULES)
