"""Wall-clock-to-loss under compute heterogeneity (ISSUE 8).

Runs SeedFlood twice on the same two-speed trace — half the swarm 4×
slower than the other half — once through the synchronous barrier loop
(every step waits for the slowest client) and once through the
event-driven EventTrainer (each client steps at its own rate, flood
messages carry per-edge delay).  Both runs see identical seeds, data, and
topology; only the clock model differs.

The headline metric is *virtual time to target loss*: the target is the
worse of the two runs' best losses (so both curves provably cross it), and
``speedup = t_barrier / t_async``.  The barrier run's loss curve is
timestamped by ``barrier_schedule`` — its step t completes when the
slowest client finishes step t.  Emits ``BENCH_async.json`` so CI tracks
the async advantage alongside the step/kernel microbenches.

Usage:
    PYTHONPATH=src python benchmarks/bench_async.py [--clients 8] [--steps 24]
                                                    [--out BENCH_async.json]
"""
import argparse
import dataclasses
import json
import time

from repro.dtrain.runner import DTrainConfig, run, sim_arch
from repro.sim import TraceSet, barrier_schedule, time_to_loss

HETEROGENEITY = 4.0     # slow clients' compute time / fast clients'


def _cfg(n: int, steps: int) -> DTrainConfig:
    return DTrainConfig(
        method="seedflood", n_clients=n, topology="ring", steps=steps,
        lr=1e-2, batch_size=4, subcge_rank=8,
        arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--clients", type=int, default=8)
    p.add_argument("--steps", type=int, default=24)
    p.add_argument("--out", default="BENCH_async.json")
    args = p.parse_args()

    trace = TraceSet.two_speed(args.clients, fast_s=1.0,
                               slow_s=HETEROGENEITY)
    cfg = _cfg(args.clients, args.steps)
    t0 = time.time()

    r_sync = run(cfg)
    barrier = barrier_schedule(trace, args.steps)
    sync_curve = list(zip(barrier, r_sync.loss_curve))

    r_async = run(dataclasses.replace(cfg, trace=trace))
    async_curve = r_async.extra["loss_vs_virtual_time"]

    # worse of the two best losses: the deepest level both runs reach
    target = max(min(l for _, l in sync_curve),
                 min(l for _, l in async_curve))
    t_sync = time_to_loss(sync_curve, target)
    t_async = time_to_loss(async_curve, target)
    speedup = t_sync / t_async if t_async > 0 else float("inf")

    out = {
        "bench": "seedflood_async",
        "clients": args.clients, "steps": args.steps,
        "heterogeneity": HETEROGENEITY,
        "target_loss": target,
        "virtual_s_to_target": {"barrier": t_sync, "async": t_async},
        "async_speedup": round(speedup, 3),
        "virtual_time_total": {"barrier": barrier[-1],
                               "async": r_async.extra["virtual_time_s"]},
        "total_bytes": {"barrier": r_sync.total_bytes,
                        "async": r_async.total_bytes},
        "final_loss": {"barrier": min(l for _, l in sync_curve),
                       "async": min(l for _, l in async_curve)},
        "bench_wall_s": round(time.time() - t0, 1),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"target loss {target:.4f}: barrier {t_sync:.1f}s vs async "
          f"{t_async:.1f}s virtual -> {speedup:.2f}x speedup")
    print(f"wrote {args.out} ({out['bench_wall_s']}s total)")


if __name__ == "__main__":
    main()
