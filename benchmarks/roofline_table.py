"""Summarize results/dryrun/*.json into the §Dry-run and §Roofline tables."""
from __future__ import annotations

import glob
import json
import os

from repro.roofline.analysis import fmt_seconds

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "jamba-1.5-large-398b", "qwen1.5-0.5b", "tinyllama-1.1b", "qwen2-72b",
    "kimi-k2-1t-a32b", "musicgen-medium", "internvl2-26b", "falcon-mamba-7b",
    "gemma3-1b", "deepseek-v2-236b",
]


def load(results_dir: str = RESULTS) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _key(r):
    return (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99,
            SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9,
            r.get("mesh", ["?"]))


def roofline_markdown(recs: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | res/dev | compute | memory | collective | dominant | useful |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=_key):
        if "error" in r or ("2x16x16" if r.get("multi_pod") else "16x16") != mesh:
            continue
        roof = r["roofline"]
        res = r.get("resident_bytes_per_device", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {res:.2f}GiB "
            f"| {fmt_seconds(roof['compute_s'])} | {fmt_seconds(roof['memory_s'])} "
            f"| {fmt_seconds(roof['collective_s'])} | **{roof['dominant']}** "
            f"| {roof['useful_ratio']:.2f} |")
    return "\n".join(lines)


def dryrun_markdown(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | params | collective ops | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=_key):
        mesh = "2x16x16" if r.get("multi_pod") else r.get("mesh", "16x16")
        if isinstance(mesh, list):
            mesh = "x".join(map(str, mesh))
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | FAIL | | | | |")
            continue
        c = r.get("collectives", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | OK | {r['compile_s']} "
            f"| {r['n_params']/1e9:.1f}B | {c.get('count', 0)} "
            f"| {c.get('total_bytes', 0):.2e} |")
    return "\n".join(lines)


def csv_rows(recs: list[dict]) -> list[tuple[str, str, str]]:
    rows = []
    for r in sorted(recs, key=_key):
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        tag = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
        if "error" in r:
            rows.append((tag, "FAIL", r.get("error", "")[:60]))
            continue
        roof = r["roofline"]
        rows.append((tag, roof["dominant"],
                     f"compute_s={roof['compute_s']:.3e} "
                     f"memory_s={roof['memory_s']:.3e} "
                     f"collective_s={roof['collective_s']:.3e} "
                     f"useful={roof['useful_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    recs = load()
    print(dryrun_markdown(recs))
    print()
    print(roofline_markdown(recs))
