# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,...]

Default (fast) mode keeps every benchmark CPU-tractable; --full uses the
paper-scale settings where feasible.  Dry-run roofline rows are included
when results/dryrun/*.json exist (produced by repro.launch.dryrun_all).
"""
import argparse
import time


def main(argv=None) -> None:
    from benchmarks import paper_tables, roofline_table

    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default="")
    p.add_argument("--skip-roofline", action="store_true")
    args = p.parse_args(argv)

    names = list(paper_tables.ALL)
    if args.only:
        names = [n for n in names
                 if any(tok in n for tok in args.only.split(","))]

    print("name,us_per_call,derived")
    for name in names:
        fn = paper_tables.ALL[name]
        t0 = time.time()
        try:
            rows = fn(fast=not args.full)
        except Exception as e:  # keep the harness running
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        for tag, val, derived in rows:
            print(f"{tag},{val},{derived}", flush=True)
        print(f"{name}/_wall,{(time.time()-t0)*1e6:.0f},benchmark wall time",
              flush=True)

    if not args.skip_roofline:
        try:
            recs = roofline_table.load()
            for tag, val, derived in roofline_table.csv_rows(recs):
                print(f"{tag},{val},{derived}")
        except Exception as e:
            print(f"roofline,ERROR,{e}")


if __name__ == "__main__":
    main()
