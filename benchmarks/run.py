# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,...] \
        [--json results.json]

Default (fast) mode keeps every benchmark CPU-tractable; --full uses the
paper-scale settings where feasible.  Dry-run roofline rows are included
when results/dryrun/*.json exist (produced by repro.launch.dryrun_all).
``--json`` additionally dumps every CSV row plus every full RunResult
(via RunResult.to_json, so numpy/JAX scalars never break serialization).
"""
import argparse
import json
import time


def main(argv=None) -> None:
    from benchmarks import paper_tables, roofline_table

    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default="")
    p.add_argument("--skip-roofline", action="store_true")
    p.add_argument("--json", default="",
                   help="also write rows + RunResult dumps to this file")
    args = p.parse_args(argv)

    paper_tables.RUN_LOG.clear()   # per-invocation, not per-process

    names = list(paper_tables.ALL)
    if args.only:
        names = [n for n in names
                 if any(tok in n for tok in args.only.split(","))]

    all_rows = []

    def emit(tag, val, derived):
        all_rows.append({"name": tag, "value": val, "derived": derived})
        print(f"{tag},{val},{derived}", flush=True)

    print("name,us_per_call,derived")
    for name in names:
        fn = paper_tables.ALL[name]
        t0 = time.time()
        try:
            rows = fn(fast=not args.full)
        except Exception as e:  # keep the harness running
            emit(name, "ERROR", f"{type(e).__name__}: {e}")
            continue
        for tag, val, derived in rows:
            emit(tag, val, derived)
        emit(f"{name}/_wall", f"{(time.time()-t0)*1e6:.0f}",
             "benchmark wall time")

    if not args.skip_roofline:
        try:
            recs = roofline_table.load()
            for tag, val, derived in roofline_table.csv_rows(recs):
                emit(tag, val, derived)
        except Exception as e:
            emit("roofline", "ERROR", str(e))

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": all_rows, "runs": paper_tables.RUN_LOG}, f,
                      indent=2)
        print(f"wrote {args.json} ({len(paper_tables.RUN_LOG)} runs)")


if __name__ == "__main__":
    main()
