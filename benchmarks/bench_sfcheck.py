"""sfcheck self-time bench (ISSUE 10): whole-tree analysis must stay fast.

The v2 engine builds a whole-program dataflow index (call graph, traced
fixpoint, donation fixpoint) before any rule runs, so this bench guards the
thing that could silently rot: a fixpoint that stops converging quickly, or
a rule that goes quadratic in tree size.  It runs the full production sweep
— all ten rules over ``src tests benchmarks examples`` — three times and
takes the best wall time (robust to runner noise), asserting the tree is
clean and the sweep fits the CI budget.

Stdlib only: no jax, no numpy — this is the one bench that must run on a
bare interpreter, because CI's lint job has no accelerator stack.

Emits ``BENCH_sfcheck.json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_sfcheck.py \
        [--budget-s 10] [--out BENCH_sfcheck.json]
"""
import argparse
import json
import sys
import time
from pathlib import Path

from repro.analysis.engine import check_paths

ROOT = Path(__file__).resolve().parents[1]
TREE = ("src", "tests", "benchmarks", "examples")


def timed_sweep():
    t0 = time.perf_counter()
    diags = check_paths([ROOT / p for p in TREE], root=ROOT)
    return time.perf_counter() - t0, diags


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--budget-s", type=float, default=10.0,
                        help="max allowed best-of-3 sweep time (default 10s)")
    parser.add_argument("--out", default="BENCH_sfcheck.json")
    args = parser.parse_args(argv)

    times, diags = [], []
    for _ in range(3):
        dt, diags = timed_sweep()
        times.append(dt)
    best = min(times)

    n_files = sum(1 for p in TREE
                  for f in (ROOT / p).rglob("*.py") if f.is_file())
    result = {
        "bench": "sfcheck",
        "files": n_files,
        "findings": len(diags),
        "best_s": round(best, 4),
        "times_s": [round(t, 4) for t in times],
        "budget_s": args.budget_s,
    }
    Path(args.out).write_text(json.dumps(result, indent=2) + "\n")
    print(json.dumps(result, indent=2))

    if diags:
        for d in diags:
            print(f"{d.path}:{d.line}:{d.col}: {d.code} {d.message}",
                  file=sys.stderr)
        print("FAIL: tree is not clean", file=sys.stderr)
        return 1
    if best > args.budget_s:
        print(f"FAIL: best sweep {best:.2f}s exceeds budget "
              f"{args.budget_s:.1f}s", file=sys.stderr)
        return 1
    print(f"OK: {n_files} files clean in {best:.2f}s "
          f"(budget {args.budget_s:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
