"""Per-step wall-time microbench for the SeedFlood simulator (ISSUE 2).

Times one training step of ``run_seedflood`` on a ring across the grid

    n ∈ {8, 64}  ×  flood backend ∈ {python, numpy}  ×
    step path ∈ {per_client, batched}

and emits ``BENCH_step.json`` so CI tracks the perf trajectory.  The
``batched`` path is the jit-resident pipeline (one fused estimate+update
dispatch and one padded-matrix replay dispatch per step); ``per_client`` is
the reference loop (2n tree-unstack/dispatch/restack cycles per step) it
replaced.  The runner records per-step wall times
(``extra["step_wall_s"]``) with the first executed step's jit-compile
time split out into ``RunResult.compile_wall_s``, so the median is
steady-state by construction.

Usage:
    PYTHONPATH=src python benchmarks/bench_step.py [--ns 8,64] [--out BENCH_step.json]
"""
import argparse
import json
import statistics
import time

from repro.dtrain.runner import DTrainConfig, run, sim_arch


def _cfg(n: int, backend: str, batched: bool, steps: int) -> DTrainConfig:
    return DTrainConfig(
        method="seedflood", n_clients=n, topology="ring", steps=steps,
        lr=1e-2, batch_size=4, subcge_rank=8, flood_backend=backend,
        batched_step=batched,
        arch=sim_arch(d_model=32, n_layers=1, n_heads=2, d_ff=64))


def time_per_step(n: int, backend: str, batched: bool, steps: int) -> float:
    r = run(_cfg(n, backend, batched, steps))
    # compile time is already diverted to r.compile_wall_s; what remains is
    # steady-state (on the per-client path a step introducing a new padded K
    # can still retrace, which the median absorbs)
    return statistics.median(r.extra["step_wall_s"])


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--ns", default="8,64")
    p.add_argument("--steps", type=int, default=6)
    p.add_argument("--out", default="BENCH_step.json")
    args = p.parse_args()
    ns = [int(x) for x in args.ns.split(",")]

    rows = []
    t0 = time.time()
    for n in ns:
        for backend in ("python", "numpy"):
            for path in ("per_client", "batched"):
                sec = time_per_step(n, backend, path == "batched", args.steps)
                rows.append({"n": n, "topology": "ring", "backend": backend,
                             "path": path, "ms_per_step": round(sec * 1e3, 3)})
                print(f"n={n:>3} backend={backend:>6} path={path:>10}: "
                      f"{sec * 1e3:8.1f} ms/step", flush=True)

    def _ms(n, backend, path):
        return next(r["ms_per_step"] for r in rows
                    if r["n"] == n and r["backend"] == backend
                    and r["path"] == path)

    speedups = {f"n={n}/{backend}":
                round(_ms(n, backend, "per_client")
                      / max(_ms(n, backend, "batched"), 1e-9), 2)
                for n in ns for backend in ("python", "numpy")}
    out = {"bench": "seedflood_step", "steps": args.steps,
           "rows": rows, "batched_speedup": speedups,
           "bench_wall_s": round(time.time() - t0, 1)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nbatched speedups: {speedups}")
    print(f"wrote {args.out} ({out['bench_wall_s']}s total)")


if __name__ == "__main__":
    main()
