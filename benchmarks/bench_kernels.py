"""Kernel-layer microbench (ISSUE 5): emits ``BENCH_kernels.json``.

Two trajectories CI tracks alongside ``BENCH_step.json``:

* ``replay_fused_vs_unfused`` — the jnp-path win the paper's Appendix A
  describes: replaying K seed messages as K materialized rank-1 axpys
  (MeZO-style, O(K·n·m)) vs one scatter into the r×r coefficient matrix
  followed by a single U A V^T fold (O(K + r·(n+m)·min(n,m))).  Both jitted
  on CPU; median wall time over post-compile reps.

* ``interpret_kernels`` — wall time of the real Pallas kernel bodies through
  the interpreter vs the jnp oracle on the same shapes.  This is a
  correctness-exercise cost trajectory (what CI pays to run the lowerings),
  NOT a perf claim: the interpreter is not the TPU.

Usage:
    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]
"""
import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import subcge
from repro.kernels import ops, ref  # sfcheck: noqa[SF006] -- benchmarks time the raw oracle against the dispatched kernels


def _median_ms(fn, reps: int = 7) -> float:
    jax.block_until_ready(fn())  # compile + drain the async warm-up
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return statistics.median(times) * 1e3


def bench_replay(n: int, m: int, r: int, K: int) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(n + K), 5)
    W = jax.random.normal(ks[0], (n, m))
    U = jax.random.normal(ks[1], (n, r))
    V = jax.random.normal(ks[2], (m, r))
    i = jax.random.randint(ks[3], (K,), 0, r)
    j = jax.random.randint(ks[4], (K,), 0, r)
    coefs = jnp.linspace(-1e-3, 1e-3, K)
    # everything is a runtime argument — closed-over constants would let XLA
    # constant-fold the replay at compile time and time nothing

    @jax.jit
    def unfused(W, U, V, i, j, coefs):
        # MeZO-style replay: K sequential rank-1 axpys, K passes over W
        def body(acc, kij):
            c, ik, jk = kij
            return acc + c * jnp.outer(U[:, ik], V[:, jk]), None
        out, _ = jax.lax.scan(body, W, (coefs, i, j))
        return out

    @jax.jit
    def fused(W, U, V, i, j, coefs):
        # paper eq. 10: scatter into A (O(K)), then one U A V^T fold
        A = subcge.scatter_A(i, j, coefs, r)
        return ref.subcge_apply(W, U, A, V)

    ms_u = _median_ms(lambda: unfused(W, U, V, i, j, coefs))
    ms_f = _median_ms(lambda: fused(W, U, V, i, j, coefs))
    return {"bench": "replay_fused_vs_unfused", "n": n, "m": m, "r": r,
            "K": K, "ms_unfused": round(ms_u, 4), "ms_fused": round(ms_f, 4),
            "speedup": round(ms_u / ms_f, 2)}


def bench_interpret(op: str) -> dict:
    # both sides jitted with runtime operands (a zero-arg jit closure would
    # be constant-folded; an eager jnp side would time Python dispatch)
    ks = jax.random.split(jax.random.PRNGKey(17), 5)
    if op == "subcge_apply":
        W = jax.random.normal(ks[0], (512, 512))
        U = jax.random.normal(ks[1], (512, 16))
        V = jax.random.normal(ks[2], (512, 16))
        A = jax.random.normal(ks[3], (16, 16))
        jit_jnp = jax.jit(lambda *a: ops.subcge_apply(*a, backend="jnp"))
        jnp_fn = lambda: jit_jnp(W, U, A, V)
        int_fn = lambda: ops.subcge_apply(W, U, A, V, backend="interpret")
    elif op == "rank1_matmul":
        x = jax.random.normal(ks[0], (256, 512))
        W = jax.random.normal(ks[1], (512, 512))
        u = jax.random.normal(ks[2], (512,))
        v = jax.random.normal(ks[3], (512,))
        jit_jnp = jax.jit(lambda *a: ops.rank1_matmul(*a, backend="jnp"))
        jnp_fn = lambda: jit_jnp(x, W, u, v, 1e-3)
        int_fn = lambda: ops.rank1_matmul(x, W, u, v, 1e-3,
                                          backend="interpret")
    else:
        raise ValueError(op)
    return {"bench": "interpret_kernels", "op": op,
            "ms_jnp": round(_median_ms(jnp_fn), 4),
            "ms_interpret": round(_median_ms(int_fn), 4)}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="BENCH_kernels.json")
    args = p.parse_args()

    rows = []
    t0 = time.time()
    for K in (16, 128, 512):
        row = bench_replay(1024, 1024, 32, K)
        rows.append(row)
        print(f"replay n=1024 r=32 K={K:>5}: unfused {row['ms_unfused']:8.3f} ms"
              f"  fused {row['ms_fused']:8.3f} ms  ({row['speedup']}x)",
              flush=True)
    for op in ("subcge_apply", "rank1_matmul"):
        row = bench_interpret(op)
        rows.append(row)
        print(f"interpret {op:>13}: jnp {row['ms_jnp']:8.3f} ms"
              f"  interpret {row['ms_interpret']:8.3f} ms", flush=True)

    out = {"rows": rows, "total_wall_s": round(time.time() - t0, 1),
           "backend": jax.default_backend()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
