"""One benchmark per paper table/figure.  Each function returns CSV rows
(name, value, derived); benchmarks.run prints them.

Mapping to the paper:
  fig1_comm_vs_perf        Fig. 1/3  — task perf vs total comm bytes/edge
  table2_client_scaling    Table 2   — GMP vs #clients, SeedFlood vs gossip
  fig5_subcge_vs_mezo      Fig. 5    — message-apply runtime vs #messages
  fig6_rank_tau            Fig. 6    — SubCGE rank/τ sensitivity
  fig7_delayed_flooding    Fig. 7    — GMP vs flooding steps k
  table1_cost_model        Table 1   — bytes/compute asymptotics, measured
  table4_runtime_breakdown Table 4   — GE vs MA phase wall-clock
  table8_cost_ledger       Table 8   — analytic per-edge cost at paper scale
                                       (OPT-1.3B, 16 clients) vs paper values

Beyond-paper benchmarks:
  beyond_subspace_momentum — momentum in the r×r coefficient space
  beyond_vector_flood      — bitset flood engine vs per-message reference
                             at n=256 clients (DESIGN.md §6)
  beyond_churn_recovery    — consensus after leave+rejoin churn, SeedFlood
                             (anti-entropy) vs gossip
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import archs
from repro.core import subcge, zo
from repro.core.messages import MESSAGE_BYTES, fmt_bytes
from repro.core.subcge import SubCGEConfig
from repro.dtrain.runner import DTrainConfig, run, sim_arch
from repro.models import params as plib
from repro.models import transformer as tf
from repro.topology import graphs


#: Every RunResult produced by a benchmark, as JSON-safe dicts
#: (RunResult.to_json coerces numpy/JAX scalars) — benchmarks.run --json
#: dumps these alongside the CSV rows.
RUN_LOG: list[dict] = []


def _run(cfg):
    r = run(cfg)
    RUN_LOG.append(r.to_json())
    return r


def _arch(fast):
    return sim_arch(d_model=48 if fast else 64, n_layers=2, n_heads=4,
                    d_ff=96 if fast else 128)


def _base_cfg(fast, **kw):
    from repro.data.synthetic import TaskConfig
    base = dict(n_clients=4 if fast else 8, topology="ring",
                steps=120 if fast else 600, lr=3e-3, batch_size=16,
                subcge_rank=32, arch=_arch(fast),
                task=TaskConfig(vocab=256, seq_len=16, concentration=0.02))
    base.update(kw)
    return DTrainConfig(**base)


# ---------------------------------------------------------------------------

def fig1_comm_vs_perf(fast: bool = True):
    rows = []
    methods = ["seedflood", "dzsgd", "dsgd", "dsgd_lora", "choco",
               "choco_lora"]
    for m in methods:
        r = _run(_base_cfg(fast, method=m))
        rows.append((f"fig1/{m}", f"{r.gmp:.4f}",
                     f"bytes_per_edge={r.bytes_per_edge:.0f}"))
    return rows


def table2_client_scaling(fast: bool = True):
    rows = []
    sizes = [4, 8] if fast else [4, 8, 16, 32]
    for m in ("seedflood", "dsgd"):
        for n in sizes:
            r = _run(_base_cfg(fast, method=m, n_clients=n))
            rows.append((f"table2/{m}/n={n}", f"{r.gmp:.4f}",
                         f"consensus_err={r.consensus_error:.2e}"))
    return rows


def fig5_subcge_vs_mezo(fast: bool = True):
    """Apply-K-messages wall time: SubCGE is ~flat in K, MeZO ~linear."""
    arch = sim_arch(d_model=128, n_layers=4, n_heads=4, d_ff=512,
                    vocab=4096)
    spec = tf.arch_spec(arch)
    params = plib.init_params(spec, 0)
    meta = plib.subcge_meta(spec)
    scfg = SubCGEConfig(rank=32, refresh_period=10_000)
    sub = subcge.subspace_at_step(meta, scfg, 0, 0)
    n_params = plib.n_params(spec)

    ks = [16, 64, 256] if fast else [16, 64, 256, 1024, 4096]
    rows = []
    # one jitted callable each, hoisted out of the K sweep: jit's shape
    # cache retraces per K on the same object instead of recompiling a
    # fresh wrapper every iteration (SF007)
    f_sub = jax.jit(lambda p, s, c: subcge.apply_messages(
        p, meta, scfg, sub, s, c))
    f_mezo = jax.jit(lambda p, s, c: zo.mezo_apply_messages(p, s, c))
    for K in ks:
        msg_seeds = jnp.arange(1, K + 1, dtype=jnp.uint32)
        coefs = jnp.full((K,), 1e-4, jnp.float32)

        f_sub(params, msg_seeds, coefs)  # compile this (K,) shape
        t0 = time.perf_counter()
        jax.block_until_ready(f_sub(params, msg_seeds, coefs))
        t_sub = time.perf_counter() - t0

        f_mezo(params, msg_seeds, coefs)
        t0 = time.perf_counter()
        jax.block_until_ready(f_mezo(params, msg_seeds, coefs))
        t_mezo = time.perf_counter() - t0

        rows.append((f"fig5/K={K}", f"{t_sub*1e6:.0f}",
                     f"mezo_us={t_mezo*1e6:.0f} speedup={t_mezo/t_sub:.1f}x "
                     f"n_params={n_params}"))
    return rows


def fig6_rank_tau(fast: bool = True):
    rows = []
    ranks = [2, 16] if fast else [2, 8, 16, 64]
    for r_ in ranks:
        r = _run(_base_cfg(fast, method="seedflood", subcge_rank=r_))
        rows.append((f"fig6/rank={r_}", f"{r.gmp:.4f}",
                     f"loss_end={np.mean(r.loss_curve[-5:]):.4f}"))
    taus = [5, 1000] if fast else [5, 50, 1000]
    for tau in taus:
        r = _run(_base_cfg(fast, method="seedflood", subcge_tau=tau))
        rows.append((f"fig6/tau={tau}", f"{r.gmp:.4f}",
                     f"loss_end={np.mean(r.loss_curve[-5:]):.4f}"))
    return rows


def fig7_delayed_flooding(fast: bool = True):
    rows = []
    n = 8 if fast else 16
    ks = [1, 2, 4] if fast else [1, 2, 4, 8]
    full = _run(_base_cfg(fast, method="seedflood", n_clients=n))
    rows.append((f"fig7/k=full(D)", f"{full.gmp:.4f}",
                 f"consensus={full.consensus_error:.1e}"))
    for k in ks:
        r = _run(_base_cfg(fast, method="seedflood", n_clients=n, flood_k=k))
        rows.append((f"fig7/k={k}", f"{r.gmp:.4f}",
                     f"consensus={r.consensus_error:.1e}"))
    return rows


def table1_cost_model(fast: bool = True):
    """Measured bytes + apply counts for the three §3 regimes."""
    rows = []
    sf = _run(_base_cfg(fast, method="seedflood", steps=10))
    gsr = _run(_base_cfg(fast, method="gossip_sr", steps=10, local_iters=2))
    dz = _run(_base_cfg(fast, method="dzsgd", steps=10))
    n_params = sf.extra["n_params"]
    rows.append(("table1/traditional_gossip_bytes", f"{dz.total_bytes:.0f}",
                 f"O(d): d={n_params}"))
    rows.append(("table1/gossip_sr_bytes", f"{gsr.total_bytes:.0f}",
                 f"O(tn) reconstructions={gsr.extra['reconstructions']} (O(tnd) compute)"))
    rows.append(("table1/seedflood_bytes", f"{sf.total_bytes:.0f}",
                 f"O(n) msgs={sf.extra['n_messages']} apply=O(n+rd)"))
    return rows


def table4_runtime_breakdown(fast: bool = True):
    """GE (gradient estimation) vs MA (message apply) phases."""
    arch = sim_arch(d_model=128, n_layers=4, n_heads=4, d_ff=512, vocab=4096)
    spec = tf.arch_spec(arch)
    params = plib.init_params(spec, 0)
    meta = plib.subcge_meta(spec)
    scfg = SubCGEConfig(rank=32, refresh_period=10_000)
    sub = subcge.subspace_at_step(meta, scfg, 0, 0)
    from repro.models.perturb import nest_subspace, sample_pert
    sub_n = nest_subspace(sub)
    toks = jax.random.randint(jax.random.PRNGKey(0), (16, 33), 0, 4096)
    K = 16
    msg_seeds = jnp.arange(1, K + 1, dtype=jnp.uint32)
    coefs = jnp.full((K,), 1e-4)

    def ge_subcge(p):
        pert = sample_pert(meta, scfg, jnp.uint32(1), scfg.eps)
        lp = tf.lm_loss(arch, p, {"tokens": toks}, sub=sub_n, pert=pert)
        lm = tf.lm_loss(arch, p, {"tokens": toks}, sub=sub_n,
                        pert=pert.with_scale(-scfg.eps))
        return (lp - lm) / (2 * scfg.eps)

    def ge_mezo(p):
        z = zo.mezo_z(p, jnp.uint32(1))
        return zo.two_point_alpha(
            lambda q: tf.lm_loss(arch, q, {"tokens": toks}), p, z, scfg.eps)

    rows = []
    for name, ge, ma in [
        ("subcge", ge_subcge,
         lambda p: subcge.apply_messages(p, meta, scfg, sub, msg_seeds, coefs)),
        ("mezo", ge_mezo,
         lambda p: zo.mezo_apply_messages(p, msg_seeds, coefs)),
    ]:
        jge = jax.jit(ge)
        jma = jax.jit(ma)
        jax.block_until_ready(jge(params))
        jax.block_until_ready(jma(params))
        t0 = time.perf_counter()
        jax.block_until_ready(jge(params))
        t_ge = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(jma(params))
        t_ma = time.perf_counter() - t0
        rows.append((f"table4/{name}", f"{(t_ge+t_ma)*1e3:.1f}",
                     f"GE_ms={t_ge*1e3:.1f} MA_ms={t_ma*1e3:.1f} K={K}"))
    return rows


def beyond_subspace_momentum(fast: bool = True):
    """Beyond-paper: momentum in SubCGE's r×r coefficient space (O(r²)
    optimizer state per leaf, consensus-safe).  Same message stream, better
    optimizer."""
    rows = []
    plain = _run(_base_cfg(fast, method="central_zo"))
    mom = _run(_base_cfg(fast, method="central_zo", momentum=0.9, lr=1e-3))
    rows.append(("beyond/zo_sgd", f"{plain.gmp:.4f}",
                 f"loss_end={np.mean(plain.loss_curve[-10:]):.4f}"))
    rows.append(("beyond/zo_subspace_momentum", f"{mom.gmp:.4f}",
                 f"beta=0.9 lr/3 loss_end={np.mean(mom.loss_curve[-10:]):.4f} "
                 f"state=O(r^2)/leaf"))
    return rows


def table8_cost_ledger(fast: bool = True):
    """Analytic per-edge cost at the PAPER's scale (OPT-1.3B, 16 clients,
    ring): our formulas vs the paper's reported Table 8 column."""
    from repro.dtrain import lora as loralib
    cfg13 = archs.get("opt-1.3b")
    d = tf.count_params(cfg13)
    lora_d = loralib.n_lora_params(
        loralib.lora_spec(tf.arch_spec(cfg13), r=8))  # exact r=8 q/v adapters
    steps_fo, steps_zo, local = 500, 5000, 5
    rounds_fo, rounds_zo = steps_fo // local, steps_zo // local
    n = 16
    rows = [
        ("table8/DSGD", fmt_bytes(d * 4 * rounds_fo),
         "paper=526.3GB (O(d)/round, fp32, one direction)"),
        ("table8/DZSGD", fmt_bytes(d * 4 * rounds_zo),
         "paper=5.26TB (ZO needs 10x rounds)"),
        ("table8/DSGD-LoRA", fmt_bytes(lora_d * 4 * rounds_fo),
         "paper=629.1MB"),
        ("table8/SeedFlood", fmt_bytes(n * steps_zo * MESSAGE_BYTES),
         f"paper=400KB ({MESSAGE_BYTES}B/msg x n x T, msgs cross each edge once)"),
    ]
    return rows


def beyond_vector_flood(fast: bool = True):
    """Bitset flood engine vs the per-message reference: one full flood of n
    messages on an n-client meshgrid (the n=256 sweep-enabling fast path)."""
    from repro.core import flood
    from repro.core.messages import Message

    rows = []
    for n in ([64, 256] if fast else [64, 256, 1024]):
        g = graphs.meshgrid(n)
        times = {}
        for backend in ("python", "numpy"):
            net = flood.make_network(g, backend=backend)
            for i in range(n):
                net.inject(i, Message(seed=1000 + i, coef=0.5, origin=i,
                                      step=0))
            t0 = time.perf_counter()
            payloads = net.rounds_arrays(net.diameter + 1)
            times[backend] = time.perf_counter() - t0
            assert all(len(p[0]) == n - 1 for p in payloads)
        rows.append((f"beyond/vector_flood/n={n}",
                     f"{times['python'] / times['numpy']:.1f}",
                     f"speedup_x python_ms={times['python']*1e3:.1f} "
                     f"numpy_ms={times['numpy']*1e3:.1f}"))
    return rows


def beyond_churn_recovery(fast: bool = True):
    """Leave+rejoin churn on a meshgrid: SeedFlood's anti-entropy restores
    exact consensus; gossip's consensus error persists (DESIGN.md §6)."""
    from repro.topology.dynamic import ChurnSchedule

    n = 16 if fast else 64
    steps = 24 if fast else 60
    churn = ChurnSchedule.leave_rejoin(
        tuple(range(0, n, 4)), steps // 4, 3 * steps // 4)
    rows = []
    for method in ("seedflood", "dzsgd"):
        r = _run(_base_cfg(fast, method=method, n_clients=n,
                          topology="meshgrid", steps=steps, churn=churn,
                          local_iters=2))
        rows.append((f"beyond/churn/{method}", f"{r.consensus_error:.3e}",
                     f"gmp={r.gmp:.4f} "
                     f"recovered={'yes' if r.consensus_error < 1e-8 else 'no'}"))
    return rows


ALL = {
    "fig1_comm_vs_perf": fig1_comm_vs_perf,
    "table2_client_scaling": table2_client_scaling,
    "fig5_subcge_vs_mezo": fig5_subcge_vs_mezo,
    "fig6_rank_tau": fig6_rank_tau,
    "fig7_delayed_flooding": fig7_delayed_flooding,
    "table1_cost_model": table1_cost_model,
    "table4_runtime_breakdown": table4_runtime_breakdown,
    "table8_cost_ledger": table8_cost_ledger,
    "beyond_subspace_momentum": beyond_subspace_momentum,
    "beyond_vector_flood": beyond_vector_flood,
    "beyond_churn_recovery": beyond_churn_recovery,
}
