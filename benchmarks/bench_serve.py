"""Serving throughput microbench (ISSUE 9): paged vs monolithic KV, plus
the live-update fold overhead per message.

Pushes 24 mixed-length requests through 8 continuous-batching slots — three
admission waves, so eviction and free-list re-admission are on the timed
path.  Both cache geometries serve the same ``max_seq=2048`` request class;
only the layout differs:

* ``paged``      — 16-token pages, per-request page tables, bucketed decode
                   (the gather width follows the longest *active* request,
                   here 32–64 positions)
* ``monolithic`` — one full-``max_seq`` page per slot (``page_size ==
                   max_seq``), i.e. the pre-paging layout: every decode
                   step attends the full provisioned capacity (2048) for
                   every slot, used or not

Each mode runs the request script once to compile every (batch,
prompt-length) prefill and every decode bucket, then three timed warm
passes (best-of-3, robust to runner noise).  The fold bench times a warm
jitted epoch-grouped fold of K=64 buffered messages into the resident
params and reports µs per message.

Emits ``BENCH_serve.json``; CI asserts paged >= monolithic tok/s.

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py [--out BENCH_serve.json]
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import archs
from repro.core.seeds import client_seed
from repro.core.subcge import SubCGEConfig
from repro.models import params as plib
from repro.models import transformer as tf
from repro.serve import DecodeServer, LiveUpdateBridge, Request, ServeConfig

SLOTS = 8
N_REQ = 24
NEW = 32
PROMPT_LENS = (16, 32)          # alternating; longest uses 48 of MAX_SEQ
MAX_SEQ = 2048                  # the request class both layouts provision


def _requests(cfg, rid0: int):
    key = jax.random.PRNGKey(0)
    reqs = []
    for i in range(N_REQ):
        L = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (L,), 0, cfg.vocab), np.int32)
        reqs.append(Request(rid=rid0 + i, prompt=prompt, max_new=NEW))
    return reqs


def _run_mode(cfg, params, page_size: int) -> dict:
    ppr = MAX_SEQ // page_size
    serve = ServeConfig(max_batch=SLOTS, page_size=page_size,
                        n_pages=SLOTS * ppr, max_seq=MAX_SEQ)
    srv = DecodeServer(cfg, params, serve)
    for r in _requests(cfg, rid0=10_000):       # warmup: compiles all shapes
        srv.submit(r)
    srv.run()
    walls, emitted = [], 0
    for rep in range(3):                        # best-of-3 warm passes
        timed = _requests(cfg, rid0=rep * 1000)
        for r in timed:
            srv.submit(r)
        t0 = time.perf_counter()
        results = srv.run()
        walls.append(time.perf_counter() - t0)
        emitted = sum(len(results[r.rid]) for r in timed)
    st = srv.stats()
    return {"page_size": page_size, "pages_per_req": ppr,
            "tok_s": round(emitted / min(walls), 1), "emitted": emitted,
            "wall_s": [round(w, 3) for w in walls],
            "prefills": st["prefills"], "decodes": st["decodes"],
            "evicted": st["evicted"]}


def _fold_overhead(cfg, params, k: int = 64) -> dict:
    scfg = SubCGEConfig(rank=8, refresh_period=8)
    bridge = LiveUpdateBridge(cfg, scfg, 0, node=0)

    def ingest():
        steps = np.arange(k, dtype=np.int32) % 16       # 2 τ-epochs
        seeds = np.array([client_seed(0, int(s), i % 4)
                          for i, s in enumerate(steps)], np.uint32)
        bridge.ingest_arrays(seeds, np.full(k, 1e-3, np.float32), steps)

    ingest()
    params = bridge.fold(params)                         # compile
    jax.block_until_ready(jax.tree.leaves(params)[0])
    reps, t0 = 5, time.perf_counter()
    for _ in range(reps):
        ingest()
        params = bridge.fold(params)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    per_fold = (time.perf_counter() - t0) / reps
    return {"k_messages": k, "ms_per_fold": round(per_fold * 1e3, 3),
            "us_per_message": round(per_fold / k * 1e6, 2)}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b")
    p.add_argument("--out", default="BENCH_serve.json")
    args = p.parse_args()
    cfg = archs.reduced(archs.get(args.arch))
    params = plib.init_params(tf.arch_spec(cfg), 0)

    t0 = time.time()
    paged = _run_mode(cfg, params, page_size=16)
    mono = _run_mode(cfg, params, page_size=MAX_SEQ)
    print(f"paged      : {paged['tok_s']:8.1f} tok/s  ({paged})")
    print(f"monolithic : {mono['tok_s']:8.1f} tok/s  ({mono})")
    fold = _fold_overhead(cfg, params)
    print(f"fold       : {fold['us_per_message']} us/message ({fold})")

    out = {"bench": "serve", "arch": cfg.name, "slots": SLOTS,
           "requests": N_REQ, "new_tokens": NEW,
           "prompt_lens": list(PROMPT_LENS),
           "paged": paged, "monolithic": mono,
           "paged_speedup": round(paged["tok_s"] / max(mono["tok_s"], 1e-9),
                                  3),
           "fold": fold, "bench_wall_s": round(time.time() - t0, 1)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\npaged speedup over monolithic: {out['paged_speedup']}x")
    print(f"wrote {args.out} ({out['bench_wall_s']}s total)")
    return 0 if paged["tok_s"] >= mono["tok_s"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
